"""repro.analysis — the invariant lint engine (DESIGN.md §12).

Each rule gets fixture-snippet positive/negative cases; the engine gets
suppression + ratchet-baseline semantics (new fails, baselined passes,
stale warns, fingerprints survive line shifts); the CLI gets JSON-schema
and exit-code checks; and the δ ledger gets the regression that pins the
set of sanctioned split sites in the real tree — adding a δ split
without registering it in an accounting helper breaks this test before
it breaks the proof.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.analysis import (LintEngine, apply_baseline, baseline_from,
                            default_rules, load_baseline, save_baseline)
from repro.analysis.rules_delta import DeltaLedgerRule
from repro.analysis.rules_fence import EpochFenceRule
from repro.analysis.rules_hostsync import HostSyncRule
from repro.analysis.rules_metrics import MetricsConformanceRule
from repro.analysis.rules_pallas import PallasBudgetRule
from repro.analysis.rules_recompile import RecompileHazardRule

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_snippet(tmp_path, rules, source, rel="src/repro/serve/plane.py",
                baseline=None, name="snippet.py"):
    p = tmp_path / name
    p.write_text(textwrap.dedent(source))
    return LintEngine(rules).run([(str(p), rel)], baseline or {})


def rule_names(report):
    return [f.rule for f in report.findings]


# -- delta-ledger ------------------------------------------------------------

class TestDeltaLedger:
    def test_raw_delta_arithmetic_flagged(self, tmp_path):
        rep = run_snippet(tmp_path, [DeltaLedgerRule()], """
            def f(cfg, S):
                return cfg.delta / S
            """, rel="src/repro/index/foo.py")
        assert rule_names(rep) == ["delta-ledger"]
        assert "accounting" in rep.findings[0].message or \
            "ledger" in rep.findings[0].message

    def test_helper_call_clean_and_ledgered(self, tmp_path):
        rep = run_snippet(tmp_path, [DeltaLedgerRule()], """
            def f(cfg, n, mp):
                return delta_prime(cfg.delta, n, mp)
            """, rel="src/repro/index/foo.py")
        assert rep.findings == []
        assert rep.ledger == [{"helper": "delta_prime",
                               "path": "src/repro/index/foo.py",
                               "line": 3, "function": "f"}]

    def test_ledger_home_may_do_raw_arithmetic(self, tmp_path):
        rep = run_snippet(tmp_path, [DeltaLedgerRule()], """
            def delta_prime(delta, n, mp):
                return delta / (n * mp)

            def shard_delta(cfg, S):
                return cfg.delta / S
            """, rel="src/repro/core/confidence.py")
        assert rep.findings == []

    def test_literal_delta_at_ci_call_site_flagged(self, tmp_path):
        rep = run_snippet(tmp_path, [DeltaLedgerRule()], """
            def f(n, mp):
                a = delta_prime(0.05, n, mp)
                b = shard_delta(delta=0.1, shards=4)
                return a + b
            """, rel="src/repro/index/foo.py")
        assert rule_names(rep) == ["delta-ledger"] * 2
        assert "0.05" in rep.findings[0].message

    def test_inlined_log_confidence_term_flagged(self, tmp_path):
        rep = run_snippet(tmp_path, [DeltaLedgerRule()], """
            import numpy as np
            def f():
                return np.log(2.0 / 0.05)
            """, rel="src/repro/index/foo.py")
        assert rule_names(rep) == ["delta-ledger"]

    def test_welford_local_delta_not_flagged(self, tmp_path):
        # a bare local named `delta` (Welford updates) is not a budget
        rep = run_snippet(tmp_path, [DeltaLedgerRule()], """
            def welford(mean, b_mean, count):
                delta = b_mean - mean
                return mean + delta * count
            """, rel="src/repro/kernels/foo.py")
        assert rep.findings == []


# -- epoch-fence -------------------------------------------------------------

class TestEpochFence:
    def test_unfenced_store_swap_flagged(self, tmp_path):
        rep = run_snippet(tmp_path, [EpochFenceRule()], """
            class Index:
                def retune(self, new):
                    self._store = new
            """, rel="src/repro/api/handle.py")
        assert rule_names(rep) == ["epoch-fence"]
        assert "'retune'" in rep.findings[0].message

    def test_init_and_swap_are_fenced(self, tmp_path):
        rep = run_snippet(tmp_path, [EpochFenceRule()], """
            class Index:
                def __init__(self, store):
                    self._store = store
                    self._epoch = 0

                def _swap(self, new):
                    self._store = new
                    self._epoch += 1
            """, rel="src/repro/api/handle.py")
        assert rep.findings == []

    def test_swap_without_epoch_bump_flagged(self, tmp_path):
        rep = run_snippet(tmp_path, [EpochFenceRule()], """
            class Index:
                def _swap_quiet(self, new):
                    self._store = new
            """, rel="src/repro/api/handle.py")
        assert rule_names(rep) == ["epoch-fence"]
        assert "never bumps _epoch" in rep.findings[0].message

    def test_allow_comment_suppresses(self, tmp_path):
        rep = run_snippet(tmp_path, [EpochFenceRule()], """
            class Index:
                def _load(self, new):
                    self._store = new  # repro-lint: allow[epoch-fence]
            """, rel="src/repro/api/handle.py")
        assert rep.findings == [] and rep.suppressed == 1


# -- host-sync ---------------------------------------------------------------

class TestHostSync:
    def test_sync_in_hot_function_flagged(self, tmp_path):
        rep = run_snippet(tmp_path, [HostSyncRule()], """
            import numpy as np
            class Plane:
                def _harvest(self, snap):
                    return np.asarray(snap.done)
            """)
        assert rule_names(rep) == ["host-sync"]

    def test_annotation_and_helper_pass(self, tmp_path):
        rep = run_snippet(tmp_path, [HostSyncRule()], """
            import numpy as np
            class Plane:
                def _harvest(self, snap, dev):
                    a = np.asarray(snap.done)  # host-sync: numpy snapshot
                    b = host_fetch(dev)
                    c = float(np.sum(host_fetch(dev)))
                    return a, b, c
            """)
        assert rep.findings == []

    def test_annotation_on_line_above_statement(self, tmp_path):
        rep = run_snippet(tmp_path, [HostSyncRule()], """
            import numpy as np
            class Plane:
                def _harvest(self, snap):
                    # host-sync: post-boundary numpy
                    worst = float(np.where(snap.ok, snap.ci,
                                           0.0).max())
                    return worst
            """)
        assert rep.findings == []

    def test_cold_functions_unconstrained(self, tmp_path):
        rep = run_snippet(tmp_path, [HostSyncRule()], """
            import numpy as np
            def build(x):
                return np.asarray(x).item()
            """)
        assert rep.findings == []

    def test_non_hot_file_unconstrained(self, tmp_path):
        rep = run_snippet(tmp_path, [HostSyncRule()], """
            import numpy as np
            class Plane:
                def _harvest(self, snap):
                    return np.asarray(snap.done)
            """, rel="src/repro/api/handle.py")
        assert rep.findings == []


# -- recompile-hazard --------------------------------------------------------

class TestRecompileHazard:
    def test_per_call_jit_flagged(self, tmp_path):
        rep = run_snippet(tmp_path, [RecompileHazardRule()], """
            import jax
            def serve(f, x):
                return jax.jit(f)(x)
            """, rel="src/repro/api/handle.py")
        assert rule_names(rep) == ["recompile-hazard"]

    def test_module_level_init_and_cached_factory_pass(self, tmp_path):
        rep = run_snippet(tmp_path, [RecompileHazardRule()], """
            import functools
            import jax

            g = jax.jit(lambda x: x)

            class Box:
                def __init__(self, f):
                    self.f = jax.jit(f)

            @functools.lru_cache(maxsize=None)
            def make(f):
                return jax.jit(f)
            """, rel="src/repro/api/handle.py")
        assert rep.findings == []

    def test_unhashable_static_default_flagged(self, tmp_path):
        rep = run_snippet(tmp_path, [RecompileHazardRule()], """
            import jax
            def f(x, opts=[1, 2]):
                return x
            g = jax.jit(f, static_argnames=("opts",))
            """, rel="src/repro/api/handle.py")
        assert rule_names(rep) == ["recompile-hazard"]
        assert "unhashable" in rep.findings[0].message

    def test_partial_jit_decorator_static_default_flagged(self, tmp_path):
        rep = run_snippet(tmp_path, [RecompileHazardRule()], """
            import functools
            import jax

            @functools.partial(jax.jit, static_argnames=("opts",))
            def f(x, opts={}):
                return x
            """, rel="src/repro/api/handle.py")
        assert rule_names(rep) == ["recompile-hazard"]

    def test_len_shape_in_pow2_file_flagged(self, tmp_path):
        rep = run_snippet(tmp_path, [RecompileHazardRule()], """
            import jax.numpy as jnp
            def pack(rows):
                return jnp.zeros((len(rows), 4))
            """, rel="src/repro/index/frontier.py")
        assert rule_names(rep) == ["recompile-hazard"]
        assert "pow2" in rep.findings[0].message

    def test_pow2_laundered_len_passes(self, tmp_path):
        rep = run_snippet(tmp_path, [RecompileHazardRule()], """
            import jax.numpy as jnp
            def pack(rows):
                return jnp.zeros((next_pow2(len(rows)), 4))
            """, rel="src/repro/index/frontier.py")
        assert rep.findings == []

    def test_len_shape_outside_pow2_files_unconstrained(self, tmp_path):
        rep = run_snippet(tmp_path, [RecompileHazardRule()], """
            import jax.numpy as jnp
            def pack(rows):
                return jnp.zeros((len(rows), 4))
            """, rel="src/repro/launch/train.py")
        assert rep.findings == []


# -- metrics-conformance -----------------------------------------------------

class TestMetricsConformance:
    def test_name_and_suffix_rules(self, tmp_path):
        rep = run_snippet(tmp_path, [MetricsConformanceRule()], """
            def wire(reg):
                reg.counter("plane_submitted_total", "no prefix")
                reg.counter("repro_plane_submitted", "counter, no _total")
                reg.gauge("repro_queue_total", "gauge with _total")
                reg.histogram("repro_Plane_ms", "uppercase")
            """, rel="src/repro/obs/foo.py")
        msgs = " ".join(f.message for f in rep.findings)
        assert len(rep.findings) == 4
        assert "_total" in msgs and "repro_" in msgs

    def test_label_vocabulary(self, tmp_path):
        rep = run_snippet(tmp_path, [MetricsConformanceRule()], """
            def wire(reg, lbl):
                reg.counter("repro_x_total", "ok", kind="a", plane="p0")
                reg.counter("repro_y_total", "bad", namepsace="oops")
                reg.histogram("repro_z_ms", "ok", buckets=(1, 2), **lbl)
            """, rel="src/repro/obs/foo.py")
        assert rule_names(rep) == ["metrics-conformance"]
        assert "namepsace" in rep.findings[0].message

    def test_dynamic_name_flagged(self, tmp_path):
        rep = run_snippet(tmp_path, [MetricsConformanceRule()], """
            def wire(reg, which):
                reg.counter(f"repro_{which}_total", "dynamic")
            """, rel="src/repro/obs/foo.py")
        assert rule_names(rep) == ["metrics-conformance"]
        assert "dynamic" in rep.findings[0].message

    def test_cross_file_kind_conflict(self, tmp_path):
        rule = MetricsConformanceRule()
        a = tmp_path / "a.py"
        a.write_text("def f(reg):\n    reg.gauge('repro_thing')\n")
        b = tmp_path / "b.py"
        b.write_text("def g(reg):\n"
                     "    reg.histogram('repro_thing')\n")
        rep = LintEngine([rule]).run(
            [(str(a), "src/repro/a.py"), (str(b), "src/repro/b.py")], {})
        conflicts = [f for f in rep.findings if "conflicting" in f.message]
        assert len(conflicts) == 1
        assert "src/repro/a.py" in conflicts[0].message
        assert "src/repro/b.py" in conflicts[0].message

    def test_non_registry_receivers_ignored(self, tmp_path):
        rep = run_snippet(tmp_path, [MetricsConformanceRule()], """
            def f(db):
                db.counter("whatever")      # not a metrics registry
            """, rel="src/repro/obs/foo.py")
        assert rep.findings == []


# -- pallas-budget -----------------------------------------------------------

_KERNEL_HEADER = textwrap.dedent("""
    import jax.numpy as jnp
    from jax.experimental import pallas as pl
    from jax.experimental.pallas import tpu as pltpu
""")


def kernel_snippet(body):
    # header and body carry different source indents; dedent each alone
    return _KERNEL_HEADER + textwrap.dedent(body)


class TestPallasBudget:
    def test_over_budget_flagged(self, tmp_path):
        rep = run_snippet(tmp_path, [PallasBudgetRule()], kernel_snippet("""
            def launch(kern, x):
                return pl.pallas_call(
                    kern,
                    grid=(4,),
                    in_specs=[pl.BlockSpec((2048, 2048),
                                           lambda i: (i, 0))],
                    out_specs=pl.BlockSpec((8, 128), lambda i: (i, 0)),
                )(x)
            """), rel="src/repro/kernels/foo.py")
        assert any("exceeds" in f.message for f in rep.findings)

    def test_within_budget_passes(self, tmp_path):
        rep = run_snippet(tmp_path, [PallasBudgetRule()], kernel_snippet("""
            def launch(kern, x, n_buf, block):
                return pl.pallas_call(
                    kern,
                    grid=(4,),
                    in_specs=[pl.BlockSpec(memory_space=pltpu.ANY),
                              pl.BlockSpec((8, 128), lambda i: (i, 0))],
                    out_specs=pl.BlockSpec((8, 128), lambda i: (i, 0)),
                    scratch_shapes=[pltpu.VMEM((n_buf, 1, block),
                                               jnp.float32)],
                )(x)
            """), rel="src/repro/kernels/foo.py")
        assert rep.findings == []

    def test_unpriceable_symbolic_dim_flagged(self, tmp_path):
        rep = run_snippet(tmp_path, [PallasBudgetRule()], kernel_snippet("""
            def launch(kern, x, mystery):
                return pl.pallas_call(
                    kern,
                    in_specs=[pl.BlockSpec((8, mystery),
                                           lambda i: (i, 0))],
                    out_specs=pl.BlockSpec((8, 128), lambda i: (i, 0)),
                )(x)
            """), rel="src/repro/kernels/foo.py")
        assert any("unpriceable" in f.message for f in rep.findings)

    def test_lane_misalignment_flagged(self, tmp_path):
        rep = run_snippet(tmp_path, [PallasBudgetRule()], kernel_snippet("""
            def launch(kern, x):
                return pl.pallas_call(
                    kern,
                    in_specs=[pl.BlockSpec((8, 200), lambda i: (i, 0))],
                    out_specs=pl.BlockSpec((8, 128), lambda i: (i, 0)),
                )(x)
            """), rel="src/repro/kernels/foo.py")
        assert any("lane" in f.message for f in rep.findings)

    def test_strided_ds_needs_divisibility_guard(self, tmp_path):
        body = kernel_snippet("""
            def kern(x_ref, o_ref, *, block):
                blk = 3
                o_ref[...] = x_ref[pl.ds(blk * block, block)]

            def launch(x, block):
                {guard}
                return pl.pallas_call(
                    kern,
                    in_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0))],
                    out_specs=pl.BlockSpec((8, 128), lambda i: (i, 0)),
                )(x)
            """)
        rep = run_snippet(
            tmp_path, [PallasBudgetRule()],
            body.format(guard="pass"), rel="src/repro/kernels/foo.py")
        assert any("divisibility" in f.message for f in rep.findings)
        rep = run_snippet(
            tmp_path, [PallasBudgetRule()],
            body.format(guard="assert x.shape[1] % block == 0"),
            rel="src/repro/kernels/foo.py")
        assert rep.findings == []

    def test_real_kernels_fit_budget(self):
        """The ISSUE's target kernels must lint clean (their symbolic dims
        are priced by DIM_BOUNDS and their strides carry guards)."""
        files = [os.path.join(REPO, "src", "repro", "kernels", f)
                 for f in ("fused_race.py", "block_pull.py")]
        rep = LintEngine([PallasBudgetRule()]).run(
            [(p, os.path.relpath(p, REPO)) for p in files], {})
        assert rep.findings == []


# -- engine: suppression + ratchet semantics ---------------------------------

class TestEngine:
    def test_standalone_allow_comment_suppresses_next_line(self, tmp_path):
        rep = run_snippet(tmp_path, [EpochFenceRule()], """
            class Index:
                def _load(self, new):
                    # repro-lint: allow[epoch-fence]
                    self._store = new
            """, rel="src/repro/api/handle.py")
        assert rep.findings == [] and rep.suppressed == 1

    def test_wildcard_allow(self, tmp_path):
        rep = run_snippet(tmp_path, [EpochFenceRule()], """
            class Index:
                def _load(self, new):
                    self._store = new  # repro-lint: allow[*]
            """, rel="src/repro/api/handle.py")
        assert rep.suppressed == 1

    def test_ratchet_new_vs_baselined_vs_stale(self, tmp_path):
        src = """
            class Index:
                def a(self, new):
                    self._store = new
                def b(self, new):
                    self._store = new
            """
        rep0 = run_snippet(tmp_path, [EpochFenceRule()], src,
                           rel="src/repro/api/handle.py")
        assert len(rep0.new) == 2 and rep0.ok is False
        base = baseline_from(rep0.findings)
        base["epoch-fence|src/repro/api/handle.py|gone"] = 1  # stale entry
        rep1 = run_snippet(tmp_path, [EpochFenceRule()], src,
                           rel="src/repro/api/handle.py", baseline=base)
        assert rep1.ok and rep1.new == [] and len(rep1.baselined) == 2
        assert rep1.stale == ["epoch-fence|src/repro/api/handle.py|gone"]
        # a THIRD identical violation exceeds the frozen budget -> new
        rep2 = run_snippet(tmp_path, [EpochFenceRule()], src + """
                def c(self, new):
                    self._store = new
            """, rel="src/repro/api/handle.py", baseline=base)
        assert len(rep2.new) == 1 and rep2.ok is False

    def test_fingerprints_survive_line_shifts(self, tmp_path):
        src = """
            class Index:
                def a(self, new):
                    self._store = new
            """
        rep0 = run_snippet(tmp_path, [EpochFenceRule()], src,
                           rel="src/repro/api/handle.py")
        base = baseline_from(rep0.findings)
        shifted = "\n\n\n# pushed down\n" + textwrap.dedent(src)
        p = tmp_path / "shifted.py"
        p.write_text(shifted)
        rep1 = LintEngine([EpochFenceRule()]).run(
            [(str(p), "src/repro/api/handle.py")], base)
        assert rep1.ok and len(rep1.baselined) == 1

    def test_unparseable_file_is_an_error_not_a_crash(self, tmp_path):
        p = tmp_path / "broken.py"
        p.write_text("def f(:\n")
        rep = LintEngine(default_rules()).run(
            [(str(p), "src/repro/broken.py")], {})
        assert rep.errors and not rep.ok

    def test_duplicate_rule_names_rejected(self):
        with pytest.raises(ValueError, match="duplicate"):
            LintEngine([EpochFenceRule(), EpochFenceRule()])

    def test_baseline_round_trip_and_version_gate(self, tmp_path):
        path = str(tmp_path / "base.json")
        save_baseline(path, {"b|p|s": 2, "a|p|s": 1})
        assert load_baseline(path) == {"a|p|s": 1, "b|p|s": 2}
        doc = json.load(open(path))
        doc["version"] = 99
        json.dump(doc, open(path, "w"))
        with pytest.raises(ValueError, match="version"):
            load_baseline(path)

    def test_apply_baseline_counts(self):
        from repro.analysis.engine import Finding
        f = lambda: Finding("r", "p", 1, 0, "m", "snip")
        new, old, stale = apply_baseline([f(), f(), f()], {"r|p|snip": 2})
        assert (len(new), len(old), stale) == (1, 2, [])


# -- CLI ---------------------------------------------------------------------

class TestCLI:
    def run_cli(self, *args, cwd=REPO):
        return subprocess.run(
            [sys.executable, os.path.join(REPO, "tools", "repro_lint.py"),
             *args], capture_output=True, text=True, cwd=cwd)

    def test_repo_is_clean_against_committed_baseline(self):
        r = self.run_cli()
        assert r.returncode == 0, r.stdout + r.stderr

    def test_json_report_schema(self, tmp_path):
        out = str(tmp_path / "report.json")
        r = self.run_cli("--json", out)
        assert r.returncode == 0, r.stdout + r.stderr
        doc = json.load(open(out))
        assert doc["version"] == 1
        assert set(doc["counts"]) == {"total", "new", "baselined",
                                      "suppressed", "stale"}
        assert doc["ok"] is True and doc["counts"]["new"] == 0
        for f in doc["findings"]:
            assert set(f) == {"rule", "path", "line", "col", "message",
                              "snippet", "status"}
            assert f["status"] in ("new", "baselined")
        assert isinstance(doc["ledger"], list) and doc["ledger"]
        assert doc["errors"] == []

    def test_new_finding_fails_without_baseline(self, tmp_path):
        bad = tmp_path / "bad.py"
        bad.write_text("class I:\n"
                       "    def f(self, new):\n"
                       "        self._store = new\n")
        r = self.run_cli("--no-baseline", str(bad))
        assert r.returncode == 1
        assert "epoch-fence" in r.stdout

    def test_baseline_update_then_clean(self, tmp_path):
        bad = tmp_path / "bad.py"
        bad.write_text("class I:\n"
                       "    def f(self, new):\n"
                       "        self._store = new\n")
        base = str(tmp_path / "base.json")
        r = self.run_cli("--baseline", base, "--baseline-update", str(bad))
        assert r.returncode == 0, r.stdout + r.stderr
        r = self.run_cli("--baseline", base, str(bad))
        assert r.returncode == 0
        assert "[baselined]" in r.stdout

    def test_syntax_error_exits_2(self, tmp_path):
        bad = tmp_path / "broken.py"
        bad.write_text("def f(:\n")
        r = self.run_cli("--no-baseline", str(bad))
        assert r.returncode == 2
        assert "error" in r.stderr.lower()


# -- the δ-split ledger regression (satellite: every split enumerable) -------

class TestDeltaSplitLedger:
    def test_ledger_enumerates_every_split_site(self):
        """The machine-generated δ-split table over the REAL tree: one
        entry per sanctioned accounting-helper call site. A new δ split
        must show up here (i.e. go through delta_prime/shard_delta) —
        and a removed one must be deleted — before the proof composes."""
        src = os.path.join(REPO, "src", "repro")
        files = []
        for dirpath, dirnames, filenames in os.walk(src):
            dirnames[:] = [d for d in dirnames if d != "__pycache__"]
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    p = os.path.join(dirpath, fn)
                    files.append((p, os.path.relpath(p, REPO)))
        rep = LintEngine([DeltaLedgerRule()]).run(files, {})
        sites = {(row["helper"], row["path"], row["function"])
                 for row in rep.ledger}
        assert sites == {
            ("delta_prime", "src/repro/core/ucb.py", "race_topk"),
            ("delta_prime", "src/repro/index/anytime.py", "__init__"),
            ("delta_prime", "src/repro/index/batched_race.py",
             "make_rounds_race"),
            ("delta_prime", "src/repro/index/batched_race.py",
             "fused_race_topk"),
            ("delta_prime", "src/repro/index/sharded.py",
             "_sharded_fused_race"),
            ("shard_delta", "src/repro/index/sharded.py", "_shard_delta"),
            ("shard_delta", "src/repro/core/distributed.py",
             "distributed_knn"),
        }
        # and the tree is free of raw δ arithmetic outside the ledger home
        assert [f for f in rep.findings] == []
