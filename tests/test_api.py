"""repro.api (DESIGN.md §6): the unified Index handle — typed QuerySpec
protocol, lifecycle (build/open/load/save), payload riding every remap,
cache + policies, deprecation shims over repro.index, and the PR-4 admin
ops: LIVE elastic re-sharding (bit-identical to the save→load-at-S′ path,
no checkpoint) and read-replica fan-out.

Device-needing parity tests skip unless the interpreter sees enough devices
(the CI job `sharded-mesh` runs this file under
XLA_FLAGS=--xla_force_host_platform_device_count=8); one subprocess test
covers the critical live-reshard parity on every tier-1 run.
"""
import os
import subprocess
import sys
import textwrap
import warnings

import jax
import numpy as np
import pytest

from repro.api import (CachePolicy, CompactionPolicy, Index, KNNResult,
                       QuerySpec, ServeStats)
from repro.configs.base import BMOConfig
from repro.core import oracle
from repro.data.synthetic import make_knn_benchmark_data

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _devices(n):
    return pytest.mark.skipif(
        jax.device_count() < n,
        reason=f"needs {n} devices (run under XLA_FLAGS="
               f"--xla_force_host_platform_device_count={n})")


def _cfg(**kw):
    base = dict(k=3, delta=0.01, block=32, batch_arms=16, metric="l2")
    base.update(kw)
    return BMOConfig(**base)


def _data(n=200, d=256, Q=4, seed=0):
    return make_knn_benchmark_data("dense", n, d, Q, seed=seed)


# ---------------------------------------------------------------------------
# QuerySpec: boundary validation + overrides
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bad", [
    dict(mode="warp"), dict(impl="cuda"), dict(cache="maybe"),
    dict(k=0), dict(delta=0.0), dict(delta=1.5), dict(max_rounds=0),
])
def test_query_spec_rejects_bad_fields(bad):
    with pytest.raises(ValueError):
        QuerySpec(**bad)


def test_query_spec_bind_and_cacheable():
    cfg = _cfg()
    assert QuerySpec().bind(cfg) is cfg            # no-op stays identical
    bound = QuerySpec(k=7, delta=0.2, max_rounds=9).bind(cfg)
    assert (bound.k, bound.delta, bound.max_rounds) == (7, 0.2, 9)
    assert QuerySpec().cacheable
    assert QuerySpec(mode="rounds").cacheable      # driver choice is free
    for spec in (QuerySpec(k=2), QuerySpec(delta=0.5),
                 QuerySpec(max_rounds=4), QuerySpec(warm_start=False),
                 QuerySpec(prior_hint=np.zeros((1, 8)))):
        assert not spec.cacheable                  # changes the contract


def test_policy_validation():
    with pytest.raises(ValueError):
        CachePolicy(capacity=-1)
    with pytest.raises(ValueError):
        CachePolicy(near_threshold=1.5)
    with pytest.raises(ValueError):
        CompactionPolicy(threshold=0.0)
    assert CompactionPolicy(threshold=2.0).threshold == 2.0   # "disabled"


def test_serve_stats_schema_and_legacy_keys():
    # schema_version bumped 1 -> 2 in PR 5 (request-plane queue/latency
    # fields) and 2 -> 3 in PR 6 (obs_* registry fields; latency
    # percentiles are now 0.0 instead of None on an empty window;
    # DESIGN.md §8 changelog note) — the v1 fields and the legacy knn_*
    # keys are unchanged; 3 -> 4 in PR 7 (QuerySpec.use_tuned,
    # DESIGN.md §9.6); 4 -> 5 in PR 8 (audit_* / slo_alerts /
    # serving_fallback / retune_requested, DESIGN.md §10); 5 -> 6 in
    # PR 9 (fleet_namespaces_resident/evicted, fleet_reloads,
    # ns_queue_depth, DESIGN.md §11)
    st = ServeStats(races=3, cache_hits=5)
    d = st.as_dict()
    assert d["schema_version"] == 6 and d["races"] == 3
    assert d["audit_sampled"] == 0 and d["audit_err_upper"] == 1.0
    assert d["serving_fallback"] is False
    assert d["fleet_namespaces_resident"] == 0 and d["fleet_reloads"] == 0
    assert d["ns_queue_depth"] is None
    assert d["plane_submitted"] == 0 and d["plane_latency_p99_ms"] == 0.0
    assert st["knn_races"] == 3 and st["knn_cache_hits"] == 5
    assert st["races"] == 3                        # new names work too
    assert "knn_shard_coord_ops" in st and "bogus" not in st
    with pytest.raises(KeyError):
        st["bogus"]


# ---------------------------------------------------------------------------
# handle lifecycle (single shard — runs anywhere)
# ---------------------------------------------------------------------------


def test_handle_build_query_mutate_save_load(tmp_path):
    corpus, queries = _data()
    ex = oracle.exact_knn(corpus, queries, 3, "l2")
    idx = Index.build(corpus, _cfg(), jax.random.PRNGKey(0),
                      payload=np.arange(200, dtype=np.int32))
    assert (idx.n_live, idx.n_shards, idx.k) == (200, 1, 3)
    res = idx.query(queries, jax.random.PRNGKey(1))
    assert isinstance(res, KNNResult)
    for i in range(4):
        assert set(res.indices[i].tolist()) == \
            set(np.asarray(ex.indices[i]).tolist())
    assert (np.diff(res.values, axis=1) >= -1e-6).all()

    # k override via kwargs == via spec; either way uncached
    r_kw = idx.query(queries, jax.random.PRNGKey(2), k=2)
    r_sp = idx.query(queries, jax.random.PRNGKey(2), spec=QuerySpec(k=2))
    assert r_kw.indices.shape == (4, 2)
    np.testing.assert_array_equal(r_kw.indices, r_sp.indices)
    # δ + budget overrides rebind the racing cfg without touching the store
    r_tight = idx.query(queries, jax.random.PRNGKey(3), delta=0.001,
                        max_rounds=500, cache="bypass")
    assert set(r_tight.indices[0].tolist()) == \
        set(np.asarray(ex.indices[0]).tolist())
    assert idx.cfg.delta == 0.01                   # store cfg untouched

    # mutation: payload rides insert + compact remaps inside the handle
    epoch0 = idx.epoch
    gids = idx.insert(queries[:1], payload=np.asarray([999], np.int32))
    assert idx.epoch == epoch0 + 1
    r2 = idx.query(queries[:1], jax.random.PRNGKey(4))
    assert int(r2.indices[0, 0]) == int(gids[0])
    assert int(idx.payload[r2.indices[0, 0]]) == 999
    idx.delete(list(range(100, 200)))
    assert idx.maybe_compact() is not None         # policy default 0.5
    assert idx.stats.compactions == 1
    r3 = idx.query(queries[:1], jax.random.PRNGKey(5))
    assert int(idx.payload[r3.indices[0, 0]]) == 999

    # persistence: payload sidecar rides save/load
    path = os.path.join(tmp_path, "idx")
    idx.save(path)
    idx2 = Index.load(path)
    assert idx2.n_live == idx.n_live
    r4 = idx2.query(queries[:1], jax.random.PRNGKey(5))
    np.testing.assert_array_equal(r4.indices, r3.indices)
    assert int(idx2.payload[r4.indices[0, 0]]) == 999


def test_handle_cache_hits_refresh_and_epoch_fence():
    corpus, queries = _data()
    idx = Index.build(corpus, _cfg(), jax.random.PRNGKey(0),
                      cache=CachePolicy(capacity=8, near_threshold=0.0))
    r1 = idx.query(queries, jax.random.PRNGKey(1))
    assert r1.cache_hits == 0 and float(r1.coord_ops.sum()) > 0
    r2 = idx.query(queries, jax.random.PRNGKey(9))     # rng must not matter
    assert r2.cache_hits == 4 and float(r2.coord_ops.sum()) == 0.0
    np.testing.assert_array_equal(r1.indices, r2.indices)
    st = idx.stats
    assert (st.races, st.raced_queries, st.cache_hits) == (1, 4, 4)
    # refresh forces a re-race and overwrites the entries
    r3 = idx.query(queries, jax.random.PRNGKey(2), cache="refresh")
    assert r3.cache_hits == 0 and idx.stats.races == 2
    # bypass leaves the cache untouched
    idx.query(queries, jax.random.PRNGKey(3), cache="bypass")
    assert idx.stats.cache_entries == 4
    # epoch fence: any mutation invalidates
    idx.delete([int(r1.indices[0, 0])])
    assert idx.stats.cache_entries == 0
    # regression: an EMPTY QueryCache is falsy (__len__) — the cumulative
    # hit/miss counters must survive invalidation, not read as 0
    assert idx.stats.cache_hits == 4 and idx.stats.cache_misses == 4
    r5 = idx.query(queries, jax.random.PRNGKey(4))
    assert r5.cache_hits == 0
    assert int(r1.indices[0, 0]) not in set(r5.indices[0].tolist())


def test_attach_payload_validation():
    corpus, _ = _data()
    idx = Index.build(corpus, _cfg(), jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="exceeds index capacity"):
        idx.attach_payload(np.zeros(idx.capacity + 1, np.int32))
    with pytest.raises(ValueError, match="does not cover"):
        idx.attach_payload(np.zeros(idx.n_live - 1, np.int32))
    idx.attach_payload(np.zeros(idx.n_live, np.int32))   # prefix covers live
    assert len(idx.payload) == idx.capacity


def test_build_gids_invalidated_on_delete_and_slot_reuse():
    """Regression: delete must mark the row's build_gid −1 so a later
    insert reusing the freed slot is not attributed to the original row."""
    corpus, _ = _data(n=64, d=64)
    idx = Index.build(corpus, _cfg(block=16), jax.random.PRNGKey(0))
    gid5 = int(idx.build_gids[5])
    idx.delete([gid5])
    assert idx.build_gids[5] == -1
    new_gid = idx.insert(corpus[5:6] * 2.0)       # reuses the freed slot
    assert int(new_gid[0]) == gid5
    assert idx.build_gids[5] == -1                # still not row 5's slot


def test_live_reshard_beyond_device_count_fails_cleanly():
    """Regression: reshard(S' > visible devices) must fail BEFORE the swap
    — the handle keeps serving at the old shard count."""
    corpus, queries = _data(n=64, d=64)
    idx = Index.build(corpus, _cfg(block=16), jax.random.PRNGKey(0))
    want = idx.query(queries, jax.random.PRNGKey(1), cache="bypass")
    with pytest.raises(RuntimeError, match="keeps serving"):
        idx.reshard(jax.device_count() + 1)
    assert idx.n_shards == 1 and idx.stats.reshards == 0
    assert idx._admin_active is None              # fence released
    got = idx.query(queries, jax.random.PRNGKey(1), cache="bypass")
    np.testing.assert_array_equal(got.indices, want.indices)


def test_admin_fence_blocks_mutations():
    corpus, _ = _data(n=64, d=64)
    idx = Index.build(corpus, _cfg(block=16), jax.random.PRNGKey(0))
    with idx._admin_op("test-op"):
        with pytest.raises(RuntimeError, match="quiesced"):
            idx.insert(corpus[:1])
        with pytest.raises(RuntimeError, match="quiesced"):
            idx.delete([0])
        with pytest.raises(RuntimeError, match="in flight"):
            idx.reshard(1)      # S'=1 is viable on any device count
    idx.delete([0])                                # fence lifted


def test_replica_fanout_single_device():
    """Read fan-out works at any device count (surplus replicas share the
    primary's placement): round-robined queries agree, mutation rebuilds."""
    corpus, queries = _data()
    idx = Index.build(corpus, _cfg(), jax.random.PRNGKey(0))
    idx.add_replicas(2)
    assert idx.stats.replicas == 2
    r1 = idx.query(queries, jax.random.PRNGKey(1), cache="bypass")
    r2 = idx.query(queries, jax.random.PRNGKey(1), cache="bypass")
    np.testing.assert_array_equal(r1.indices, r2.indices)
    gid = idx.insert(queries[:1])                  # invalidates replicas
    r3 = idx.query(queries[:1], jax.random.PRNGKey(2), cache="bypass")
    r4 = idx.query(queries[:1], jax.random.PRNGKey(2), cache="bypass")
    assert int(r3.indices[0, 0]) == int(gid[0])
    np.testing.assert_array_equal(r3.indices, r4.indices)


# ---------------------------------------------------------------------------
# deprecation shims over repro.index
# ---------------------------------------------------------------------------


def test_deprecation_shims_warn_once_and_forward():
    import repro.index as old

    corpus, queries = _data(n=80, d=64)
    cfg = _cfg(block=16)
    old._DEPRECATION_WARNED.clear()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        store = old.build_index(corpus, cfg, jax.random.PRNGKey(0))
        store2 = old.build_index(corpus, cfg, jax.random.PRNGKey(0))
        res = old.index_knn(store, queries, jax.random.PRNGKey(1))
        old.index_knn(store2, queries, jax.random.PRNGKey(1))
    dep = [x for x in w if issubclass(x.category, DeprecationWarning)]
    # exactly once per symbol, not per call
    msgs = sorted(str(x.message).split(" ")[0] for x in dep)
    assert msgs == ["repro.index.build_index", "repro.index.index_knn"]
    # and the shim forwards to the very implementation the new API calls
    from repro.index import batched_race, builder
    assert old.index_knn.__wrapped__ is batched_race.index_knn
    assert old.build_index.__wrapped__ is builder.build_index
    # results identical to the new surface on the same store + rng
    handle = Index.open(store)
    new = handle.query(queries, jax.random.PRNGKey(1), cache="bypass")
    np.testing.assert_array_equal(np.asarray(res.indices), new.indices)
    np.testing.assert_array_equal(np.asarray(res.values), new.values)


def test_every_shimmed_symbol_is_wrapped():
    import repro.index as old
    for name, (mod, _) in old._SHIMS.items():
        fn = getattr(old, name)
        assert fn.__wrapped__ is getattr(mod, name), name
    # the store/state types pass through un-deprecated
    from repro.index import (FrontierState, IndexStore,  # noqa: F401
                             ShardedIndexStore, ShardedKNNResult)


# ---------------------------------------------------------------------------
# LIVE elastic re-sharding: parity vs the save→load-at-S′ path
# ---------------------------------------------------------------------------


def _build_for(kind: str, shards: int, seed: int = 3):
    if kind == "sparse":
        from repro.core.datasets import SparseDataset
        from repro.data.synthetic import clustered_sparse
        corpus = clustered_sparse(120, 512, seed=seed)
        ds = SparseDataset.build(corpus)
        queries = (ds.indices[:2], ds.values[:2], ds.nnz[:2])
        cfg = BMOConfig(k=3, delta=0.01, block=1, batch_arms=16,
                        pulls_per_round=8, init_pulls=16, metric="l1",
                        sparse=True)
    else:
        corpus, queries = make_knn_benchmark_data("dense", 120, 256, 2,
                                                  seed=seed)
        cfg = _cfg(block=64, rotate=(kind == "rotated"))
    idx = Index.build(corpus, cfg, jax.random.PRNGKey(0), shards=shards,
                      payload=np.arange(120, dtype=np.int32))
    return idx, queries


@_devices(8)
@pytest.mark.parametrize("kind", ["dense", "rotated", "sparse"])
@pytest.mark.parametrize("s_from,s_to", [(1, 4), (4, 2), (4, 8)])
def test_live_reshard_parity_vs_save_load(tmp_path, kind, s_from, s_to):
    """Property (PR-4 acceptance): ``Index.reshard(S')`` on a LIVE handle —
    with pending tombstones — returns bit-identical top-k ids/values to the
    save_sharded_index → load_sharded_index(shards=S') path, with the
    payload remapped and the query cache invalidated, and NO checkpoint
    written by the live path."""
    live, queries = _build_for(kind, s_from)
    live.delete(live.build_gids[[5, 17, 101]])     # pending tombstones
    if kind != "sparse":                           # warm the cache too
        live.query(queries, jax.random.PRNGKey(6))
        assert live.stats.cache_entries > 0

    path = os.path.join(tmp_path, "idx")
    live.save(path)
    ref = Index.load(path, shards=s_to)
    want = ref.query(queries, jax.random.PRNGKey(7), cache="bypass")

    n_files_before = sum(len(f) for _, _, f in os.walk(tmp_path))
    old_ids = live.reshard(s_to)
    assert sum(len(f) for _, _, f in os.walk(tmp_path)) == n_files_before
    got = live.query(queries, jax.random.PRNGKey(7), cache="bypass")

    np.testing.assert_array_equal(got.indices, want.indices)   # bit-exact
    np.testing.assert_array_equal(got.values, want.values)
    np.testing.assert_array_equal(live.payload, ref.payload)
    assert live.n_shards == s_to and live.stats.reshards == 1
    assert live.stats.cache_entries == 0           # fence cleared the LRU
    assert old_ids.shape == (live.capacity,)
    # payload still names the original rows through the remap
    rows = live.payload[got.indices]
    assert (live.build_gids[rows] == got.indices).all()


@_devices(4)
def test_live_reshard_then_serve_and_mutate():
    """After a live 4→2 re-shard the handle keeps serving AND mutating:
    inserts route by global id in the new addressing."""
    live, queries = _build_for("dense", 4)
    live.reshard(2)
    q0 = np.asarray(queries)[:1]
    gid = live.insert(q0 + 1e-3, payload=np.asarray([-1], np.int32))
    res = live.query(q0, jax.random.PRNGKey(2), cache="bypass")
    assert int(res.indices[0, 0]) == int(gid[0])
    assert int(live.payload[res.indices[0, 0]]) == -1


def test_live_reshard_parity_subprocess(tmp_path):
    """Dense 4→2 live-reshard parity on a forced 4-device host mesh — runs
    on every tier-1 invocation regardless of the parent's device count."""
    prog = f"""
        import os, numpy as np, jax
        from repro.api import Index
        from repro.configs.base import BMOConfig
        from repro.data.synthetic import make_knn_benchmark_data
        corpus, queries = make_knn_benchmark_data("dense", 128, 256, 2, seed=3)
        cfg = BMOConfig(k=3, delta=0.01, block=32, batch_arms=16, metric="l2")
        live = Index.build(corpus, cfg, jax.random.PRNGKey(0), shards=4,
                           payload=np.arange(128, dtype=np.int32))
        live.delete(live.build_gids[[3, 50]])
        path = r"{str(tmp_path)}/idx"
        live.save(path)
        ref = Index.load(path, shards=2)
        want = ref.query(queries, jax.random.PRNGKey(7), cache="bypass")
        live.reshard(2)
        got = live.query(queries, jax.random.PRNGKey(7), cache="bypass")
        np.testing.assert_array_equal(got.indices, want.indices)
        np.testing.assert_array_equal(got.values, want.values)
        np.testing.assert_array_equal(live.payload, ref.payload)
        assert live.n_shards == 2 and live.stats.reshards == 1
        print("OK")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c",
                          "import repro\n" + textwrap.dedent(prog)],
                         capture_output=True, text=True, env=env,
                         cwd=ROOT, timeout=560)
    assert out.returncode == 0 and "OK" in out.stdout, \
        f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-3000:]}"


@_devices(4)
def test_replica_fanout_on_disjoint_meshes():
    """Sharded replicas land on disjoint device slices (S=2, r=2 on 4
    devices) and round-robined queries agree with the primary's."""
    corpus, queries = _data(n=128, d=256)
    idx = Index.build(corpus, _cfg(block=64), jax.random.PRNGKey(0),
                      shards=2)
    want = idx.query(queries, jax.random.PRNGKey(1), cache="bypass")
    idx.add_replicas(2)
    r1 = idx.query(queries, jax.random.PRNGKey(1), cache="bypass")  # primary
    r2 = idx.query(queries, jax.random.PRNGKey(1), cache="bypass")  # replica
    np.testing.assert_array_equal(r1.indices, want.indices)
    np.testing.assert_array_equal(r2.indices, want.indices)
    reps = idx._replica_stores
    assert reps is not None and len(reps) == 2
    assert reps[1].device_offset == 2              # disjoint slice
