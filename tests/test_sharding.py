"""Sharding rules: divisibility fallbacks, pspec derivation, dedup."""
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.sharding.spec import ParamSpec, Rules, make_rules, param_pspecs


AX = {"data": 16, "model": 16}


def test_basic_tp_fsdp():
    r = make_rules(fsdp=True, tp=True, axis_sizes=AX)
    # mlp weight (embed, mlp): fsdp on embed, tp on mlp
    assert r.pspec(("embed", "mlp"), (4096, 16384)) == P("data", "model")


def test_heads_not_divisible_falls_back_to_head_dim():
    r = make_rules(tp=True, axis_sizes=AX)
    # 40 heads don't divide 16 → heads replicated, head_dim picks up model
    ps = r.pspec(("embed", "heads", "head_dim"), (5120, 40, 128))
    assert ps == P(None, None, "model")


def test_heads_divisible_takes_model_and_dedups_head_dim():
    r = make_rules(tp=True, axis_sizes=AX)
    ps = r.pspec(("embed", "heads", "head_dim"), (4096, 32, 128))
    assert ps == P(None, "model")  # head_dim dropped (model already used)


def test_mqa_kv_head():
    r = make_rules(tp=True, axis_sizes=AX)
    ps = r.pspec(("embed", "kv_heads", "head_dim"), (6144, 1, 128))
    assert ps == P(None, None, "model")


def test_batch_one_replicated():
    r = make_rules(tp=True, axis_sizes=AX)
    assert r.pspec(("batch", "seq"), (1, 524288)) == P()


def test_multi_pod_batch():
    r = make_rules(tp=True, multi_pod=True,
                   axis_sizes={"pod": 2, "data": 16, "model": 16})
    ps = r.pspec(("batch", None, None), (256, 4096, 1024))
    assert ps == P(("pod", "data"))


def test_multi_pod_partial_divisibility():
    # batch 16 divides data(16) but not pod×data(32): drop trailing axes
    r = make_rules(tp=True, multi_pod=True,
                   axis_sizes={"pod": 2, "data": 16, "model": 16})
    ps = r.pspec(("batch",), (16,))
    assert ps == P("pod") or ps == P()  # greedy trailing drop keeps "pod"


def test_param_pspecs_tree():
    r = make_rules(fsdp=False, tp=True, axis_sizes=AX)
    tree = {"w": ParamSpec((64, 128), jnp.float32, ("embed", "mlp")),
            "ln": ParamSpec((64,), jnp.float32, ("act_embed",))}
    specs = param_pspecs(tree, r)
    assert specs["w"] == P(None, "model")
    assert specs["ln"] == P()


@settings(max_examples=50, deadline=None)
@given(
    st.lists(st.sampled_from(["embed", "mlp", "heads", "kv_heads", "head_dim",
                              "vocab", None]), min_size=1, max_size=4),
    st.lists(st.integers(1, 512), min_size=1, max_size=4),
    st.booleans(), st.booleans(),
)
def test_pspec_always_divisible_property(axes, dims, fsdp, tp):
    """Any pspec produced must have mesh extents dividing the dims."""
    n = min(len(axes), len(dims))
    axes, dims = tuple(axes[:n]), tuple(dims[:n])
    r = make_rules(fsdp=fsdp, tp=tp, axis_sizes=AX)
    ps = r.pspec(axes, dims)
    for i, entry in enumerate(ps):
        if entry is None:
            continue
        names = (entry,) if isinstance(entry, str) else entry
        extent = 1
        for nm in names:
            extent *= AX[nm]
        assert dims[i] % extent == 0


def test_no_axis_reused_within_tensor():
    r = make_rules(fsdp=True, tp=True, axis_sizes=AX)
    ps = r.pspec(("embed", "mlp", "vocab"), (4096, 16384, 32000))
    used = []
    for entry in ps:
        if entry is None:
            continue
        used += [entry] if isinstance(entry, str) else list(entry)
    assert len(used) == len(set(used))
