"""HLO cost model (roofline): trip-count-scaled FLOPs/bytes/collectives."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.hlo import analyze_hlo, parse_module, multipliers


def _compile_text(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_scan_flops_scaled():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        c, _ = jax.lax.scan(body, x, None, length=12)
        return c

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    cost = analyze_hlo(_compile_text(f, x, w))
    want = 12 * 2 * 64 * 128 * 128
    assert abs(cost.flops - want) / want < 0.05


def test_nested_scan_flops():
    def f(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        c, _ = jax.lax.scan(outer, x, None, length=5)
        return c

    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    cost = analyze_hlo(_compile_text(f, x, w))
    want = 15 * 2 * 32 * 64 * 64
    assert abs(cost.flops - want) / want < 0.05


def test_no_loop_flops():
    def f(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    cost = analyze_hlo(_compile_text(f, a, b))
    want = 2 * 128 * 256 * 64
    assert abs(cost.flops - want) / want < 0.05


def test_bytes_reasonable_for_matmul():
    def f(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    cost = analyze_hlo(_compile_text(f, a, b))
    lo = 3 * 256 * 256 * 4            # read a, b, write out
    assert lo <= cost.bytes_accessed <= 4 * lo


def test_entry_detection():
    def f(x):
        return jnp.sum(x * 2)

    comps, entry = parse_module(_compile_text(f, jax.ShapeDtypeStruct((8,), jnp.float32)))
    assert entry is not None and entry in comps
    assert multipliers(comps, entry)[entry] == 1.0


def test_collective_bytes_on_host_mesh():
    """psum inside a scan on an 8-device host platform — collective bytes
    must be scaled by the trip count (subprocess: own XLA device count)."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, "src")
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.roofline.hlo import analyze_hlo
        mesh = jax.make_mesh((8,), ("d",), axis_types=(jax.sharding.AxisType.Auto,))

        def inner(x):
            def body(c, _):
                return jax.lax.psum(c, "d") * 0.5, None
            c, _ = jax.lax.scan(body, x, None, length=10)
            return c

        f = jax.shard_map(inner, mesh=mesh, in_specs=P(), out_specs=P(),
                          check_vma=False)
        x = jax.ShapeDtypeStruct((1024,), jnp.float32)
        cost = analyze_hlo(jax.jit(f).lower(x).compile().as_text())
        # 10 × all-reduce of 1024 f32 × 2 (ring halves) = 81920 bytes min
        assert cost.coll_bytes >= 10 * 1024 * 4, cost.coll_bytes
        print("OK", cost.coll_bytes)
    """)
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, cwd=".", timeout=300)
    assert "OK" in out.stdout, out.stdout + out.stderr


def test_cpu_upcast_artifact_detection():
    from repro.roofline.hlo import cpu_upcast_artifact_bytes

    def f(w, x):
        def body(c, _):
            return jnp.tanh(c @ w.astype(jnp.float32)), None
        c, _ = jax.lax.scan(body, x, None, length=4)
        return c

    w = jax.ShapeDtypeStruct((128, 128), jnp.bfloat16)
    x = jax.ShapeDtypeStruct((8, 128), jnp.float32)
    txt = _compile_text(f, w, x)
    art = cpu_upcast_artifact_bytes(txt)
    assert art >= 128 * 128 * 4  # the hoisted f32 copy of w


def test_roofline_terms_of_fused_epoch_pull_lowering():
    """repro.tune's seed pass scores candidates by roofline terms of the
    fused epoch kernel's lowering — pin the contract it depends on: the
    terms are finite and positive at real (R, B) grid points, and grow
    monotonically with both the fused pull count T = R·P and the arm
    batch B (more pulled blocks / more arms = strictly more modeled
    work, never less)."""
    import functools

    from repro.kernels import ops as kops
    from repro.roofline.analysis import analyze_compiled

    n, d_pad, block, Q = 1024, 512, 128, 8

    def lower(B, T):
        x = jnp.zeros((n, d_pad), jnp.float32)
        qs = jnp.zeros((Q, d_pad), jnp.float32)
        arm = jnp.zeros((Q, B), jnp.int32)
        blk = jnp.zeros((Q, B, T), jnp.int32)
        fn = functools.partial(kops.fused_epoch_pull, block=block,
                               metric="l2", impl="ref")
        compiled = jax.jit(fn).lower(x, qs, arm, blk).compile()
        return analyze_compiled(
            compiled, arch="cpu", shape=f"B{B} T{T}", mesh_name="test",
            chips=1, model_flops=float(Q * B * T * block))

    lo = lower(16, 4)      # (R=2, P=2, B=16)
    hi = lower(64, 16)     # (R=8, P=2, B=64)
    for terms in (lo, hi):
        for v in (terms.t_compute, terms.t_memory, terms.hlo_flops,
                  terms.hlo_bytes):
            assert np.isfinite(v) and v > 0.0, terms.to_dict()
        assert terms.bottleneck in ("compute", "memory", "collective")
    # 4× arms × 4× pulls: modeled work must grow strictly, and at least
    # linearly in one of the two resources
    assert hi.hlo_flops > lo.hlo_flops
    assert hi.hlo_bytes > lo.hlo_bytes
    assert max(hi.t_compute, hi.t_memory) >= \
        4.0 * max(lo.t_compute, lo.t_memory)
