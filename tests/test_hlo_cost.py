"""HLO cost model (roofline): trip-count-scaled FLOPs/bytes/collectives."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.hlo import analyze_hlo, parse_module, multipliers


def _compile_text(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_scan_flops_scaled():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        c, _ = jax.lax.scan(body, x, None, length=12)
        return c

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    cost = analyze_hlo(_compile_text(f, x, w))
    want = 12 * 2 * 64 * 128 * 128
    assert abs(cost.flops - want) / want < 0.05


def test_nested_scan_flops():
    def f(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        c, _ = jax.lax.scan(outer, x, None, length=5)
        return c

    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    cost = analyze_hlo(_compile_text(f, x, w))
    want = 15 * 2 * 32 * 64 * 64
    assert abs(cost.flops - want) / want < 0.05


def test_no_loop_flops():
    def f(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    cost = analyze_hlo(_compile_text(f, a, b))
    want = 2 * 128 * 256 * 64
    assert abs(cost.flops - want) / want < 0.05


def test_bytes_reasonable_for_matmul():
    def f(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    cost = analyze_hlo(_compile_text(f, a, b))
    lo = 3 * 256 * 256 * 4            # read a, b, write out
    assert lo <= cost.bytes_accessed <= 4 * lo


def test_entry_detection():
    def f(x):
        return jnp.sum(x * 2)

    comps, entry = parse_module(_compile_text(f, jax.ShapeDtypeStruct((8,), jnp.float32)))
    assert entry is not None and entry in comps
    assert multipliers(comps, entry)[entry] == 1.0


def test_collective_bytes_on_host_mesh():
    """psum inside a scan on an 8-device host platform — collective bytes
    must be scaled by the trip count (subprocess: own XLA device count)."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, "src")
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.roofline.hlo import analyze_hlo
        mesh = jax.make_mesh((8,), ("d",), axis_types=(jax.sharding.AxisType.Auto,))

        def inner(x):
            def body(c, _):
                return jax.lax.psum(c, "d") * 0.5, None
            c, _ = jax.lax.scan(body, x, None, length=10)
            return c

        f = jax.shard_map(inner, mesh=mesh, in_specs=P(), out_specs=P(),
                          check_vma=False)
        x = jax.ShapeDtypeStruct((1024,), jnp.float32)
        cost = analyze_hlo(jax.jit(f).lower(x).compile().as_text())
        # 10 × all-reduce of 1024 f32 × 2 (ring halves) = 81920 bytes min
        assert cost.coll_bytes >= 10 * 1024 * 4, cost.coll_bytes
        print("OK", cost.coll_bytes)
    """)
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, cwd=".", timeout=300)
    assert "OK" in out.stdout, out.stdout + out.stderr


def test_cpu_upcast_artifact_detection():
    from repro.roofline.hlo import cpu_upcast_artifact_bytes

    def f(w, x):
        def body(c, _):
            return jnp.tanh(c @ w.astype(jnp.float32)), None
        c, _ = jax.lax.scan(body, x, None, length=4)
        return c

    w = jax.ShapeDtypeStruct((128, 128), jnp.bfloat16)
    x = jax.ShapeDtypeStruct((8, 128), jnp.float32)
    txt = _compile_text(f, w, x)
    art = cpu_upcast_artifact_bytes(txt)
    assert art >= 128 * 128 * 4  # the hoisted f32 copy of w
