"""PR-8 coverage (DESIGN.md §10): the shadow δ-auditor's exact oracle
(parity with the racing drivers across dense / sparse / sharded boxes),
the Wilson / Clopper–Pearson error-rate bounds, the off-critical-path
property of the audit reservoir, the injected-failure regression (a wrong
answer below the plane is caught, bundled, and replayed by
``tools/replay_audit.py``), the multi-window burn-rate SLO engine
(rising-edge fire + resolve), the recall guard → fallback → re-tune
chain on the live handle, and the health snapshot rollup.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.api import Deadline, Index, QuerySpec
from repro.configs.base import BMOConfig
from repro.data.synthetic import clustered_sparse, make_knn_benchmark_data
from repro.obs import ObsContext
from repro.obs.audit import (DeltaAuditor, FlightRecorder, check_topk,
                             clopper_pearson_upper, exact_theta_of,
                             exact_topk, load_bundle, replay_bundle,
                             wilson_upper)
from repro.obs.slo import (SLO, AlertSink, BurnRule, SLOEngine,
                           default_slos, plane_sources)
from repro.serve.plane import PlaneConfig, RequestPlane

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(prog: str, devices: int = 4, timeout: int = 560):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c",
                          "import repro\n" + textwrap.dedent(prog)],
                         capture_output=True, text=True, env=env,
                         cwd=ROOT, timeout=timeout)
    assert out.returncode == 0 and "OK" in out.stdout, \
        f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-3000:]}"


def _dense_index(n=256, d=256, Q=4, seed=1, **kw):
    corpus, queries = make_knn_benchmark_data("dense", n, d, Q, seed=seed)
    cfg = dict(k=4, delta=0.05, block=64, batch_arms=16, metric="l2")
    cfg.update(kw)
    return (Index.build(corpus, BMOConfig(**cfg), jax.random.PRNGKey(0)),
            queries)


# -- estimator bounds --------------------------------------------------------

def test_error_bounds_properties():
    # no evidence -> no claim
    assert wilson_upper(0, 0) == 1.0
    assert clopper_pearson_upper(0, 0) == 1.0
    # monotone in failures, bounded in [point estimate, 1]
    prev = 0.0
    for f in range(0, 20):
        u = wilson_upper(f, 20)
        assert u >= f / 20 - 1e-12 and u <= 1.0
        assert u > prev
        prev = u
    # more clean evidence -> tighter bound
    assert wilson_upper(0, 1000) < wilson_upper(0, 100) < wilson_upper(0, 10)
    # the exact CP bound has coverage >= the asymptotic Wilson bound on
    # clean streaks (both shrink toward 0; CP is the conservative one)
    for n in (10, 100, 1000):
        assert clopper_pearson_upper(0, n) >= wilson_upper(0, n)
    # CP closed form on zero failures: 1 - (1-conf)^(1/n)
    n = 50
    assert clopper_pearson_upper(0, n, confidence=0.95) == \
        pytest.approx(1.0 - 0.05 ** (1.0 / n), rel=1e-4)
    with pytest.raises(ValueError):
        wilson_upper(5, 3)
    with pytest.raises(ValueError):
        clopper_pearson_upper(-1, 3)


# -- the exact oracle --------------------------------------------------------

def test_exact_oracle_parity_dense():
    idx, queries = _dense_index(delta=0.01)
    ids, vals = exact_topk(idx.store, queries, 4)
    res = idx.query(queries, jax.random.PRNGKey(3), cache="bypass")
    # a certified race answers the exact top-k with prob >= 1-δ; at δ=0.01
    # on 4 queries a mismatch here means the oracle is wrong, not the race
    chk = check_topk(idx.store, queries, res.indices, 4)
    assert chk.mismatches == 0
    assert np.all(np.diff(vals, axis=1) >= -1e-12)      # ascending θ
    # exact_theta_of agrees with exact_topk on its own ids
    theta = exact_theta_of(idx.store, queries, ids)
    assert np.allclose(theta, vals, rtol=1e-5, atol=1e-6)
    # invalid / tombstoned ids price at inf
    bad = ids.copy()
    bad[0, 0] = -1
    assert np.isinf(exact_theta_of(idx.store, queries, bad)[0, 0])
    idx.delete([int(ids[1, 0])])
    assert np.isinf(exact_theta_of(idx.store, queries, ids)[1, 0])


def test_exact_oracle_parity_sparse():
    from repro.core.datasets import SparseDataset
    corpus = clustered_sparse(150, 2048, seed=4)
    ds = SparseDataset.build(corpus)
    queries = (ds.indices[:3], ds.values[:3], ds.nnz[:3])
    cfg = BMOConfig(k=3, delta=0.01, block=1, batch_arms=16,
                    pulls_per_round=8, init_pulls=16, metric="l1",
                    sparse=True)
    idx = Index.build(corpus, cfg, jax.random.PRNGKey(0))
    res = idx.query(queries, jax.random.PRNGKey(5), cache="bypass")
    chk = check_topk(idx.store, queries, res.indices, 3)
    assert chk.mismatches == 0
    ids, vals = exact_topk(idx.store, queries, 3)
    assert np.allclose(np.sort(vals, axis=1), vals)


def test_exact_oracle_parity_sharded():
    _run("""
        import jax, numpy as np
        from repro.api import Index
        from repro.configs.base import BMOConfig
        from repro.data.synthetic import make_knn_benchmark_data
        from repro.obs.audit import check_topk, exact_topk

        corpus, queries = make_knn_benchmark_data("dense", 256, 256, 4,
                                                  seed=2)
        cfg = BMOConfig(k=4, delta=0.01, block=64, batch_arms=16,
                        metric="l2")
        idx = Index.build(corpus, cfg, jax.random.PRNGKey(0), shards=4)
        res = idx.query(queries, jax.random.PRNGKey(3), cache="bypass")
        chk = check_topk(idx.store, queries, res.indices, 4)
        assert chk.mismatches == 0, chk.row_mismatch
        ids, vals = exact_topk(idx.store, queries, 4)
        assert set(map(int, ids.ravel())) == \\
            set(map(int, np.asarray(res.indices).ravel()))
        print("OK")
    """)


def test_check_topk_flags_wrong_and_duplicate_ids():
    idx, queries = _dense_index()
    res = idx.query(queries, jax.random.PRNGKey(3), cache="bypass")
    served = np.asarray(res.indices).copy()
    assert check_topk(idx.store, queries, served, 4).mismatches == 0
    # a duplicated neighbor id = some true neighbor missing -> mismatch,
    # regardless of how the distances tie
    dup = served.copy()
    dup[0, 0] = dup[0, 1]
    chk = check_topk(idx.store, queries, dup, 4)
    assert chk.row_mismatch[0] and chk.mismatches == 1
    # an id with θ far above the exact k-th -> mismatch on that row only
    ids, vals = exact_topk(idx.store, queries, idx.store.capacity // 2)
    wrong = served.copy()
    wrong[1, 0] = int(ids[1, -1])          # the worst candidate we know
    chk = check_topk(idx.store, queries, wrong, 4)
    assert chk.row_mismatch[1] and not chk.row_mismatch[0]


# -- the shadow auditor on the plane ----------------------------------------

def test_auditor_clean_run_and_off_critical_path():
    idx, queries = _dense_index()
    obs = ObsContext("t", enabled=True)
    plane = RequestPlane(idx, PlaneConfig(audit_rate=1.0), obs=obs)
    for i in range(3):
        plane.submit(queries + 0.001 * i, rng=jax.random.PRNGKey(10 + i),
                     cache="bypass")
    plane.drain()
    # the oracle has NOT run yet: sampling at _finish only copies arrays
    # into the reservoir — drain()'s steps all started non-idle
    assert plane.auditor.pending == 3
    assert plane.auditor.sampled_rows == 0
    # an idle step (nothing queued, nothing racing) pays for ONE item
    plane.step()
    assert plane.auditor.pending == 2
    assert plane.audit_flush() == 2
    s = plane.auditor.summary()
    assert s["mismatch_rows"] == 0
    assert s["sampled_rows"] == 3 * queries.shape[0]
    assert 0.0 < s["err_upper"] < 1.0
    st = plane.stats
    assert st.audit_sampled == s["sampled_rows"]
    assert st.audit_mismatches == 0 and st.audit_pending == 0
    assert st.audit_err_upper == pytest.approx(s["err_upper"])


def test_auditor_skips_uncertified_and_stale_epochs():
    idx, queries = _dense_index(n=512, d=1024)
    plane = RequestPlane(idx, PlaneConfig(audit_rate=1.0),
                         obs=ObsContext("t", enabled=True))
    # a deadline exit is partial: it never claimed the full 1-δ contract
    plane.submit(queries, rng=jax.random.PRNGKey(1), cache="bypass",
                 deadline=Deadline(ms=1e-3))
    plane.drain()
    assert plane.auditor.summary()["skipped"]["uncertified"] >= 1
    # sample a certified ticket, then mutate the store before the oracle
    # runs: the ground truth moved, the item must be skipped, not judged
    plane.submit(queries, rng=jax.random.PRNGKey(2), cache="bypass")
    plane.drain()
    assert plane.auditor.pending == 1
    idx.insert(np.asarray(queries[:1]))           # epoch fence bump
    assert plane.audit_flush() == 1               # processed = skipped
    s = plane.auditor.summary()
    assert s["skipped"]["stale_epoch"] == 1
    assert s["sampled_rows"] == 0


def test_auditor_sampling_rate_and_reservoir_bound():
    idx, queries = _dense_index()
    auditor = DeltaAuditor(idx, rate=0.0, seed=7)
    r = auditor.offer(trace_id="t", tenant="a", store_epoch=idx.epoch,
                      contract="default", k=2, delta=0.05,
                      queries=np.asarray(queries),
                      served_ids=np.zeros((4, 2), np.int64),
                      served_vals=np.zeros((4, 2)))
    assert not r and auditor.pending == 0         # rate 0 samples nothing
    auditor = DeltaAuditor(idx, rate=1.0, reservoir=2, seed=7)
    for i in range(5):
        auditor.offer(trace_id=f"t{i}", tenant="a", store_epoch=idx.epoch,
                      contract="default", k=2, delta=0.05,
                      queries=np.asarray(queries),
                      served_ids=np.zeros((4, 2), np.int64),
                      served_vals=np.zeros((4, 2)))
    assert auditor.pending == 2                   # drop-oldest, bounded
    assert auditor.dropped == 3
    with pytest.raises(ValueError):
        DeltaAuditor(idx, rate=1.5)
    with pytest.raises(ValueError):
        auditor.offer(trace_id="t", tenant="a", store_epoch=0,
                      contract="nonsense", k=2, delta=0.05,
                      queries=np.asarray(queries),
                      served_ids=np.zeros((4, 2), np.int64),
                      served_vals=np.zeros((4, 2)))


def test_injected_failure_caught_bundled_and_replayed(tmp_path):
    """Satellite 3: corrupt ONE served result BELOW the plane — scheduler,
    cache and certification all believe it — and assert the auditor flags
    exactly that ticket, writes a replayable bundle, and
    tools/replay_audit.py reproduces the mismatch offline."""
    idx, queries = _dense_index()
    obs = ObsContext("t", enabled=True)
    bundles = tmp_path / "bundles"
    plane = RequestPlane(idx, PlaneConfig(audit_rate=1.0,
                                          audit_dir=str(bundles)), obs=obs)
    good = plane.submit(queries, rng=jax.random.PRNGKey(1), cache="bypass")
    plane.drain()

    real_build = plane._build_result

    def corrupted(entry, terminal, reason):
        res = real_build(entry, terminal, reason)
        if terminal and reason == "certified":
            res.indices[0, 0] = res.indices[0, 1]
            plane._build_result = real_build       # one ticket only
        return res

    plane._build_result = corrupted
    bad = plane.submit(queries + 0.002, rng=jax.random.PRNGKey(2),
                       cache="bypass")
    plane.drain()
    plane.audit_flush()
    s = plane.auditor.summary()
    assert s["mismatch_rows"] == 1
    assert len(s["bundles"]) == 1
    bundle = s["bundles"][0]

    doc, arrays = load_bundle(bundle)
    assert doc["trace_id"] == bad.trace_id        # that ticket, not good's
    assert doc["trace_id"] != good.trace_id
    assert doc["mismatch_rows"] == [0]
    assert arrays["served_ids"][0, 0] == arrays["served_ids"][0, 1]
    # the bundle carries the ticket's trace events as evidence
    assert any(e.get("trace") == bad.trace_id for e in doc["events"])

    # in-process replay on the live handle: deterministic reproduction
    rep = replay_bundle(idx, bundle)
    assert rep["reproduced"] and rep["epoch_match"]
    assert rep["mismatch_rows_now"] == [0]

    # offline replay through the CLI against a save/load round-trip
    index_dir = tmp_path / "idx"
    idx.save(str(index_dir))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tools", "replay_audit.py"),
         "--index-dir", str(index_dir), "--json",
         str(tmp_path / "replay.json"), bundle],
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=560)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "REPRODUCED" in out.stdout
    rep_doc = json.loads((tmp_path / "replay.json").read_text())
    assert rep_doc["reports"][0]["reproduced"]

    # stats + health reflect the violation (err_rate 1/20 == δ boundary
    # is fine; the Wilson upper bound is what trips `violated`)
    st = plane.stats
    assert st.audit_mismatches == 1
    from repro.obs import health_snapshot
    doc = health_snapshot(plane=plane)
    assert not doc["ok"] and len(doc["violations"]) == 1
    json.dumps(doc)                               # JSON-safe end to end


# -- SLO burn-rate engine ----------------------------------------------------

def _engine(budget=0.05, min_events=1, obs=None):
    rules = (BurnRule(long_s=60.0, short_s=5.0, factor=10.0,
                      severity="page"),
             BurnRule(long_s=300.0, short_s=30.0, factor=2.0,
                      severity="ticket"))
    clock = {"t": 0.0}
    slo = SLO(name="recall", source="recall", budget=budget, rules=rules,
              min_events=min_events)
    eng = SLOEngine((slo,), obs=obs, clock=lambda: clock["t"])
    return eng, clock


def test_slo_fire_and_resolve_edges():
    obs = ObsContext("t", enabled=True)
    eng, clock = _engine(budget=0.05, obs=obs)
    # clean traffic: no alerts no matter how long
    for t in range(0, 120, 5):
        clock["t"] = float(t)
        assert eng.observe({"recall": (0.0, float(10 * (t + 1)))}) == []
    assert eng.active_alerts == []
    # everything failing: burn = 1/0.05 = 20x >= both factors -> both
    # rules fire ONCE (rising edge), not on every observation
    clock["t"] = 125.0
    fired = eng.observe({"recall": (600.0, 1250.0)})
    assert {a.severity for a in fired} == {"page", "ticket"}
    clock["t"] = 130.0
    assert eng.observe({"recall": (650.0, 1300.0)}) == []   # still burning
    assert len(eng.active_alerts) == 2
    assert eng.sink.active("recall")
    # recovery: clean traffic pushes the short window burn under the
    # factor -> the page rule (5s short window) resolves first
    for t in range(135, 460, 5):
        clock["t"] = float(t)
        eng.observe({"recall": (650.0, 650.0 + 10.0 * t)})
    assert eng.active_alerts == []
    resolves = [a for a in eng.sink.alerts if not a.active]
    assert len(resolves) == 2
    # the lifetime counter saw exactly the two rising edges
    fired_total = sum(m.value for m in obs.registry.collect()
                      if m.name == "repro_slo_alerts_total")
    assert fired_total == 2
    assert eng.alerts_fired == 2


def test_slo_min_events_gate_and_validation():
    eng, clock = _engine(budget=0.01, min_events=100)
    clock["t"] = 1.0
    # 5 bad of 5: 100% bad but under min_events -> no alert
    assert eng.observe({"recall": (5.0, 5.0)}) == []
    clock["t"] = 2.0
    assert eng.observe({"recall": (200.0, 200.0)}) != []
    with pytest.raises(ValueError):
        SLO(name="x", source="x", budget=0.0)
    with pytest.raises(ValueError):
        BurnRule(long_s=5.0, short_s=60.0, factor=2.0)
    with pytest.raises(ValueError):
        BurnRule(long_s=60.0, short_s=5.0, factor=2.0, severity="sms")
    with pytest.raises(ValueError):
        SLOEngine((SLO(name="a", source="s", budget=0.1),
                   SLO(name="a", source="s", budget=0.2)))


def test_default_slos_and_plane_sources():
    slos = default_slos(0.05, latency_ms=50.0)
    assert [s.name for s in slos] == ["recall", "latency", "shed"]
    assert slos[0].budget == 0.05                 # budget IS the paper's δ
    idx, queries = _dense_index()
    plane = RequestPlane(idx, PlaneConfig(audit_rate=1.0),
                         obs=ObsContext("t", enabled=True))
    plane.submit(queries, rng=jax.random.PRNGKey(1), cache="bypass")
    plane.drain()
    plane.audit_flush()
    src = plane_sources(plane, latency_ms=50.0)
    bad, total = src["recall"]
    assert total == queries.shape[0] and bad == 0.0
    assert src["shed"][1] == 1.0                  # 1 submission, 0 shed
    lat_bad, lat_total = src["latency"]
    assert lat_total == 1.0 and 0.0 <= lat_bad <= lat_total
    # engine state() round-trips to JSON
    eng = SLOEngine(slos)
    eng.observe(src)
    json.dumps(eng.state())


# -- recall guard on the live handle ----------------------------------------

def test_recall_guard_fallback_and_retune_chain():
    from repro.obs.slo import Alert
    from repro.serve.scale import (RecallGuardPolicy, ScaleDecision,
                                   apply_guard)
    from repro.tune import TunedConfig
    idx, queries = _dense_index()
    # install a tuned config the cheap way (identity knobs, measured stamp)
    tuned = TunedConfig.from_cfg(idx.cfg).with_measured(epoch_ms=1.0,
                                                        round_ms=0.0)
    idx._apply_tuned(tuned)
    assert idx._serving_tuned(QuerySpec())
    epoch_before = idx.epoch

    sink = AlertSink()
    guard = RecallGuardPolicy(sink)
    assert guard.recommend(idx.stats).action == "none"     # healthy

    sink.emit(Alert(slo="recall", severity="page", rule="10x/60s",
                    burn_long=20.0, burn_short=20.0, bad_frac=1.0,
                    budget=0.05, at=0.0))
    d1 = guard.recommend(idx.stats)
    assert d1.action == "fallback_untuned" and "burning" in d1.reason
    assert apply_guard(idx, d1)
    assert idx.serving_fallback
    # fallback is a COST decision, not a correctness event: no epoch bump
    assert idx.epoch == epoch_before
    assert not idx._serving_tuned(QuerySpec())             # served untuned
    assert idx._query_cfg(QuerySpec()) == QuerySpec().bind(idx._base_cfg)

    d2 = guard.recommend(idx.stats)
    assert d2.action == "retune"
    assert apply_guard(idx, d2)
    assert idx.retune_requested and "burning" in idx.retune_reason
    assert guard.recommend(idx.stats).action == "none"     # chain complete

    # a fresh tune() lifts the fallback and clears the re-tune flag
    idx.tune(rng=jax.random.PRNGKey(13), queries=np.asarray(queries))
    assert not idx.serving_fallback and not idx.retune_requested
    assert idx._serving_tuned(QuerySpec())

    with pytest.raises(ValueError):
        ScaleDecision(action="reboot")
    assert not apply_guard(idx, ScaleDecision())           # none is a no-op


def test_health_snapshot_shapes(tmp_path):
    from repro.obs import dump_health, health_snapshot
    idx, queries = _dense_index()
    plane = RequestPlane(idx, PlaneConfig(audit_rate=1.0),
                         obs=ObsContext("t", enabled=True))
    plane.submit(queries, rng=jax.random.PRNGKey(1), cache="bypass")
    plane.drain()
    plane.audit_flush()
    slos = default_slos(float(idx.cfg.delta))
    eng = SLOEngine(slos)
    eng.observe(plane_sources(plane))
    p = tmp_path / "health.json"
    doc = dump_health(str(p), plane=plane, slo=eng)
    parsed = json.loads(p.read_text())
    assert parsed["ok"] is True
    assert parsed["schema_version"] == doc["schema_version"]
    assert parsed["stats"]["audit_sampled"] == queries.shape[0]
    assert parsed["index"]["delta"] == pytest.approx(0.05)
    assert parsed["audit"]["mismatch_rows"] == 0
    assert [s["name"] for s in parsed["slo"]["slos"]] == ["recall", "shed"]
    # a forced fallback alone flips the rollup
    idx.force_untuned(True)
    assert health_snapshot(plane=plane)["ok"] is False
