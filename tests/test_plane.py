"""Request-plane test coverage (DESIGN.md §7): the anytime certified-prefix
contract across boxes and shard counts, scheduler termination (deadline /
effort budget), anytime monotonicity, tenant fairness under an adversarial
heavy tenant, backpressure shedding, the mutation epoch fence, the blocking
``plane.query`` shim's cache/counter parity, the ServeStats v3 schema, and
the ``ScalePolicy`` autoscaling hints on synthetic load traces.

The sharded (S=4) anytime contract runs as a subprocess on a forced
4-device host mesh (the test_distributed.py harness), so it covers every
tier-1 invocation regardless of the parent's device count.
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.api import Deadline, EffortBudget, Index, QuerySpec
from repro.configs.base import BMOConfig
from repro.data.synthetic import clustered_sparse, make_knn_benchmark_data
from repro.serve.plane import PlaneConfig, RequestPlane

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(prog: str, devices: int = 4, timeout: int = 560):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c",
                          "import repro\n" + textwrap.dedent(prog)],
                         capture_output=True, text=True, env=env,
                         cwd=ROOT, timeout=timeout)
    assert out.returncode == 0 and "OK" in out.stdout, \
        f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-3000:]}"


def _dense_cfg(**kw):
    base = dict(k=4, delta=0.01, block=64, batch_arms=16, pulls_per_round=2,
                metric="l2")
    base.update(kw)
    return BMOConfig(**base)


def _dense_index(n=256, d=512, Q=4, seed=1, **kw):
    corpus, queries = make_knn_benchmark_data("dense", n, d, Q, seed=seed)
    cfg = _dense_cfg(**kw)
    return Index.build(corpus, cfg, jax.random.PRNGKey(0)), queries


def _sparse_index():
    from repro.core.datasets import SparseDataset
    corpus = clustered_sparse(200, 2048, seed=4)
    ds = SparseDataset.build(corpus)
    queries = (ds.indices[:4], ds.values[:4], ds.nnz[:4])
    cfg = BMOConfig(k=3, delta=0.01, block=1, batch_arms=16,
                    pulls_per_round=8, init_pulls=16, metric="l1",
                    sparse=True)
    return Index.build(corpus, cfg, jax.random.PRNGKey(0)), queries


def _prefix_ok(partial, full):
    """The anytime contract: certified entries are exact (CI 0), ordered,
    and exactly the prefix of the full-certification answer."""
    Q, k = partial.indices.shape
    for q in range(Q):
        cc = int(partial.certified_count[q])
        assert 0 <= cc <= k
        assert partial.indices[q][:cc].tolist() == \
            full.indices[q][:cc].tolist(), (q, cc)
        np.testing.assert_allclose(partial.values[q][:cc],
                                   full.values[q][:cc], rtol=1e-5)
        assert (partial.ci_radii[q][:cc] == 0.0).all()
        # never an uncertified arm ranked above a certified one: positions
        # beyond the prefix carry nonzero CI or are non-certified estimates
        if cc < k:
            tail = partial.ci_radii[q][cc:]
            assert not np.any(tail < 0)


# ---------------------------------------------------------------------------
# anytime certified-prefix contract
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["dense", "rotated", "sparse"])
def test_anytime_prefix_matches_full_certification(kind):
    """For ANY effort cutoff, the certified prefix of the partial answer
    equals the full-certification answer's prefix (acceptance criterion)."""
    if kind == "sparse":
        idx, queries = _sparse_index()
    else:
        idx, queries = _dense_index(rotate=(kind == "rotated"))
    rng = jax.random.PRNGKey(7)
    full = RequestPlane(idx).query(queries, rng=rng, cache="bypass")
    assert full.terminal and full.reason == "certified"
    assert (full.certified_count == idx.k).all()
    assert (np.diff(full.values, axis=1) >= -1e-6).all()   # sorted exact θ

    hit_partial = False
    for epochs in (1, 2, 3, 5, 8):
        plane = RequestPlane(idx)
        res = plane.query(queries, rng=rng, cache="bypass",
                          budget=EffortBudget(epochs=epochs))
        assert res.terminal
        _prefix_ok(res, full)
        if res.reason == "budget":
            hit_partial = True
            assert res.epochs <= epochs
    assert hit_partial      # at least one cutoff actually truncated a race


def test_anytime_monotonic_certified_count():
    """Streaming one ticket: certified_count never decreases, the certified
    prefix never changes once emitted, and the terminal answer certifies
    all k (acceptance: anytime-monotonicity)."""
    idx, queries = _dense_index()
    plane = RequestPlane(idx)
    t = plane.submit(queries, rng=jax.random.PRNGKey(3), cache="bypass")
    prev = None
    seen_prefix = [[] for _ in range(t.n_queries)]
    for partial in plane.stream(t):
        cc = partial.certified_count
        if prev is not None:
            assert (cc >= prev).all(), "certified_count regressed"
        for q in range(t.n_queries):
            ids = partial.indices[q][: int(cc[q])].tolist()
            assert ids[: len(seen_prefix[q])] == seen_prefix[q], \
                "certified prefix was reordered"
            seen_prefix[q] = ids
        prev = cc
    assert t.result.reason == "certified"
    assert (t.result.certified_count == idx.k).all()


def test_sharded_anytime_prefix_subprocess():
    """Dense + rotated + sparse at S=4 on a forced 4-device host mesh:
    deadline/budget partials return a certified prefix of the
    full-certification answer (acceptance criterion, sharded half)."""
    _run("""
        import jax, numpy as np
        from repro.api import EffortBudget, Index
        from repro.configs.base import BMOConfig
        from repro.core.datasets import SparseDataset
        from repro.data.synthetic import (clustered_sparse,
                                          make_knn_benchmark_data)
        from repro.serve.plane import RequestPlane

        def check(idx, queries, rng):
            full = RequestPlane(idx).query(queries, rng=rng, cache="bypass")
            assert full.reason == "certified"
            assert (full.certified_count == idx.k).all()
            hit = False
            for epochs in (1, 2, 4, 8):
                res = RequestPlane(idx).query(
                    queries, rng=rng, cache="bypass",
                    budget=EffortBudget(epochs=epochs))
                hit |= res.reason == "budget"
                Q, k = res.indices.shape
                for q in range(Q):
                    cc = int(res.certified_count[q])
                    assert res.indices[q][:cc].tolist() == \\
                        full.indices[q][:cc].tolist(), (epochs, q, cc)
                    assert (res.ci_radii[q][:cc] == 0).all()
            assert hit

        corpus, queries = make_knn_benchmark_data("dense", 256, 512, 4,
                                                  seed=1)
        for kw in (dict(), dict(rotate=True)):
            cfg = BMOConfig(k=4, delta=0.01, block=64, batch_arms=16,
                            pulls_per_round=2, metric="l2", **kw)
            idx = Index.build(corpus, cfg, jax.random.PRNGKey(0), shards=4)
            check(idx, queries, jax.random.PRNGKey(7))

        corpus = clustered_sparse(200, 2048, seed=4)
        ds = SparseDataset.build(corpus)
        cfg = BMOConfig(k=3, delta=0.01, block=1, batch_arms=16,
                        pulls_per_round=8, init_pulls=16, metric="l1",
                        sparse=True)
        idx = Index.build(corpus, cfg, jax.random.PRNGKey(0), shards=4)
        check(idx, (ds.indices[:4], ds.values[:4], ds.nnz[:4]),
              jax.random.PRNGKey(5))
        print("OK")
    """, devices=4)


# ---------------------------------------------------------------------------
# scheduler termination
# ---------------------------------------------------------------------------


def test_deadline_expiry_returns_certified_prefix():
    """A wall-clock deadline terminates with reason='deadline' and a valid
    certified prefix — never an uncertified arm above a certified one."""
    idx, queries = _dense_index(n=512, d=1024)
    rng = jax.random.PRNGKey(11)
    full = RequestPlane(idx).query(queries, rng=rng, cache="bypass")
    plane = RequestPlane(idx)
    res = plane.query(queries, rng=rng, cache="bypass",
                      deadline=Deadline(ms=1.0))
    assert res.terminal and res.reason == "deadline"
    assert plane.stats.plane_deadline_exits == 1
    _prefix_ok(res, full)
    assert (res.certified_count < idx.k).any()   # 1 ms cannot certify all


def test_effort_budget_coord_ops():
    idx, queries = _dense_index()
    plane = RequestPlane(idx)
    res = plane.query(queries, rng=jax.random.PRNGKey(2), cache="bypass",
                      budget=EffortBudget(coord_ops=1.0))
    assert res.terminal and res.reason == "budget"
    assert plane.stats.plane_budget_exits == 1


def test_queued_ticket_deadline_expires_without_racing():
    """A ticket whose deadline lapses while still queued terminates with an
    empty certified prefix instead of racing a dead request."""
    idx, queries = _dense_index()
    plane = RequestPlane(idx, PlaneConfig(max_active_groups=1))
    t1 = plane.submit(queries, rng=jax.random.PRNGKey(0), cache="bypass")
    t2 = plane.submit(queries + 1.0, rng=jax.random.PRNGKey(1),
                      cache="bypass", deadline=Deadline(ms=0.5))
    import time
    time.sleep(0.002)
    plane.drain()
    assert t1.result.reason == "certified"
    assert t2.result.reason == "deadline"
    assert (t2.result.certified_count == 0).all()
    assert t2.epochs == 0


# ---------------------------------------------------------------------------
# fairness / backpressure
# ---------------------------------------------------------------------------


def test_fairness_one_adversarial_heavy_tenant():
    """Admission round-robins across tenants: a light tenant arriving after
    a heavy tenant's flood still gets into the very next race group."""
    idx, queries = _dense_index()
    plane = RequestPlane(idx, PlaneConfig(max_group_queries=8,
                                          max_active_groups=1))
    heavy = [plane.submit(queries + i, tenant="heavy",
                          rng=jax.random.PRNGKey(i), cache="bypass")
             for i in range(6)]
    light = plane.submit(queries + 100.0, tenant="light",
                         rng=jax.random.PRNGKey(99), cache="bypass")
    plane.step()
    # first group admitted one heavy + the light ticket (8-row budget)
    assert light.admitted_at is not None
    assert heavy[0].admitted_at is not None
    assert all(t.admitted_at is None for t in heavy[1:])
    plane.drain()
    assert light.finished_at <= min(t.finished_at for t in heavy[2:])
    assert all(t.result.reason == "certified" for t in heavy + [light])


def test_backpressure_sheds_with_reason():
    idx, queries = _dense_index()
    plane = RequestPlane(idx, PlaneConfig(max_queue=2))
    tickets = [plane.submit(queries + i, rng=jax.random.PRNGKey(i),
                            cache="bypass") for i in range(5)]
    shed = [t for t in tickets if t.status == "shed"]
    assert len(shed) == 3 and all(t.reason == "queue_full" for t in shed)
    assert all(t.result.terminal and t.result.reason == "shed"
               for t in shed)
    assert plane.stats.plane_shed == 3
    plane.drain()
    assert all(t.result.reason == "certified"
               for t in tickets if t.status != "shed")


# ---------------------------------------------------------------------------
# mutation fence
# ---------------------------------------------------------------------------


def test_mutation_fence_complete_serves_old_epoch():
    """on_mutation='complete': an in-flight ticket finishes against the
    (immutable) pre-mutation store and its result is tagged with that
    epoch — never mixed."""
    idx, queries = _dense_index()
    plane = RequestPlane(idx, PlaneConfig(on_mutation="complete"))
    epoch0 = idx.epoch
    t = plane.submit(queries, rng=jax.random.PRNGKey(1), cache="bypass")
    plane.step()                          # ticket racing against epoch0
    idx.insert(np.asarray(queries, np.float32))   # epoch bump mid-race
    assert idx.epoch == epoch0 + 1
    plane.drain()
    assert t.result.reason == "certified"
    assert t.result.epoch == epoch0       # completed against the old store
    assert plane.stats.plane_readmitted == 0
    # regression: an old-epoch result must NOT poison the new epoch's
    # query LRU — a fresh identical query re-races on the mutated store
    fresh = plane.query(queries, rng=jax.random.PRNGKey(5))
    assert fresh.epoch == idx.epoch
    assert float(np.sum(fresh.coord_ops)) > 0   # raced, not cache-served


def test_mutation_fence_readmit_regression():
    """Regression (satellite): a mutation mid-race with
    on_mutation='readmit' re-admits in-flight tickets against the new
    store — results are valid for the NEW epoch (a deleted id can never be
    served) and never mix epochs."""
    idx, queries = _dense_index()
    plane = RequestPlane(idx, PlaneConfig(on_mutation="readmit"))
    epoch0 = idx.epoch
    # learn the uncontested top-1 of row 0, then delete it mid-race
    probe = RequestPlane(idx).query(queries, rng=jax.random.PRNGKey(9),
                                    cache="bypass")
    top0 = int(probe.indices[0, 0])
    t = plane.submit(queries, rng=jax.random.PRNGKey(1), cache="bypass")
    plane.step()                          # in flight against epoch0
    idx.delete([top0])
    assert idx.epoch == epoch0 + 1
    plane.drain()
    assert t.result.reason == "certified"
    assert t.result.epoch == idx.epoch    # re-raced on the new store
    assert plane.stats.plane_readmitted == 1
    assert top0 not in set(t.result.indices.ravel().tolist())
    # parity with a fresh query on the mutated store
    fresh = RequestPlane(idx).query(queries, rng=jax.random.PRNGKey(2),
                                    cache="bypass")
    assert set(t.result.indices[0].tolist()) == \
        set(fresh.indices[0].tolist())


# ---------------------------------------------------------------------------
# blocking shim parity + stats schema
# ---------------------------------------------------------------------------


def test_blocking_shim_matches_index_query_and_caches():
    idx, queries = _dense_index()
    plane = RequestPlane(idx)
    res = plane.query(queries, rng=jax.random.PRNGKey(1))
    ref = idx.query(queries, jax.random.PRNGKey(1), cache="bypass")
    for q in range(queries.shape[0]):
        assert set(res.indices[q].tolist()) == \
            set(np.asarray(ref.indices[q]).tolist())
    assert float(np.sum(res.coord_ops)) > 0
    # exact repeat is served from the shared LRU at zero cost
    res2 = plane.query(queries, rng=jax.random.PRNGKey(8))
    assert float(np.sum(res2.coord_ops)) == 0.0
    np.testing.assert_array_equal(res.indices, res2.indices)
    st = plane.stats
    assert st.cache_hits == queries.shape[0]
    # partial (deadline/budget) results must never poison the cache
    plane.query(queries + 1.0, rng=jax.random.PRNGKey(2),
                budget=EffortBudget(epochs=1))
    assert plane.stats.cache_entries == st.cache_entries


def test_serve_stats_v3_schema_and_legacy_keys():
    """PR-6 satellite: as_dict() carries the obs_* fields; the v2
    plane_* and legacy ``knn_*`` keys keep working (schema bumped 3 -> 4
    in PR 7 for QuerySpec.use_tuned, 4 -> 5 in PR 8 for the audit/SLO
    fields, 5 -> 6 in PR 9 for the fleet_*/ns_queue_depth fields)."""
    from repro.api import ServeStats
    from repro.api.spec import SCHEMA_VERSION
    assert SCHEMA_VERSION == 6
    idx, queries = _dense_index()
    plane = RequestPlane(idx)
    plane.query(queries, rng=jax.random.PRNGKey(1))
    d = plane.stats.as_dict()
    assert d["schema_version"] == 6
    for f in ("plane_submitted", "plane_shed", "plane_queue_depth",
              "plane_latency_p99_ms", "obs_events", "obs_event_drops",
              "obs_epoch_ms", "obs_latency_ms"):
        assert f in d
    st = plane.stats
    assert st["knn_races"] == st.races == 1
    assert st["knn_cache_misses"] == st.cache_misses
    assert "knn_cache_hits" in st
    # a default ServeStats still satisfies the legacy surface
    legacy = ServeStats()
    assert legacy["knn_near_hits"] == 0
    with pytest.raises(KeyError):
        legacy["nope"]


def test_plane_config_validation():
    with pytest.raises(ValueError, match="max_active_groups"):
        PlaneConfig(max_active_groups=0)
    with pytest.raises(ValueError, match="on_mutation"):
        PlaneConfig(on_mutation="nope")
    with pytest.raises(ValueError, match="max_queue"):
        PlaneConfig(max_queue=0)


def test_deadline_overflow_reaches_non_head_tickets():
    """Regression: with every group slot busy, a deadline ticket queued
    BEHIND its own tenant's unbounded ticket must still reach the overflow
    slot (the EDF scan covers whole queues, not just heads)."""
    idx, queries = _dense_index()
    plane = RequestPlane(idx, PlaneConfig(max_active_groups=1))
    blocker = plane.submit(queries, rng=jax.random.PRNGKey(0),
                           cache="bypass")
    plane.step()                          # the only slot is now busy
    unbounded = plane.submit(queries + 1.0, tenant="t",
                             rng=jax.random.PRNGKey(1), cache="bypass")
    urgent = plane.submit(queries + 2.0, tenant="t",
                          rng=jax.random.PRNGKey(2), cache="bypass",
                          deadline=Deadline(ms=30000.0))
    plane.step()
    assert urgent.admitted_at is not None     # took the overflow slot
    assert unbounded.admitted_at is None      # still parked behind the slot
    plane.drain()
    assert all(t.terminal for t in (blocker, unbounded, urgent))


def test_requeue_preserves_same_tenant_fifo():
    """Regression: when admission pops more race-incompatible buckets than
    free slots, the unlaunched tickets are requeued in original order."""
    idx, queries = _dense_index()
    plane = RequestPlane(idx, PlaneConfig(max_active_groups=1))
    t1 = plane.submit(queries, rng=jax.random.PRNGKey(0), k=2,
                      cache="bypass")
    t2 = plane.submit(queries, rng=jax.random.PRNGKey(1), k=3,
                      cache="bypass")
    t3 = plane.submit(queries, rng=jax.random.PRNGKey(2), k=4,
                      cache="bypass")
    plane.step()                          # launches t1's bucket only
    assert t1.admitted_at is not None
    # queues are keyed (tenant, namespace) since the fleet refactor (§11.2)
    queued_ids = [e.ticket.id
                  for e in plane._queues[("default", None)]]
    assert queued_ids == [t2.id, t3.id]   # FIFO survives the requeue
    plane.drain()
    assert [t.result.reason for t in (t1, t2, t3)] == ["certified"] * 3


def test_submit_validates_unraceable_specs():
    """Regression: invalid specs are rejected at submit — admitted into a
    coalesced bucket they would abort co-admitted tickets mid-step."""
    idx, queries = _dense_index()
    plane = RequestPlane(idx)
    with pytest.raises(ValueError, match="rounds"):
        plane.submit(queries, mode="rounds")
    with pytest.raises(ValueError, match="live slots"):
        plane.submit(queries, k=10000)
    with pytest.raises(ValueError, match="dense"):
        plane.submit((queries, queries, queries[:, 0]))


def test_launch_failure_sheds_instead_of_orphaning():
    """Regression: a race that becomes unlaunchable between submit and
    admission (here: deletes drop n_live below k) sheds the affected
    tickets with a reason — drain() always quiesces, nothing is orphaned."""
    idx, queries = _dense_index()
    plane = RequestPlane(idx)
    t1 = plane.submit(queries, rng=jax.random.PRNGKey(0), cache="bypass")
    t2 = plane.submit(queries + 1.0, rng=jax.random.PRNGKey(1),
                      cache="bypass")
    idx.delete(list(range(254)))          # 2 live slots < k=4
    plane.drain()
    assert t1.terminal and t2.terminal
    assert t1.status == "shed" and t1.reason.startswith("rejected")
    assert "live slots" in t1.reason


def test_query_spec_deadline_budget_validation():
    with pytest.raises(ValueError, match="Deadline"):
        QuerySpec(deadline=5.0)
    with pytest.raises(ValueError, match="EffortBudget"):
        QuerySpec(budget=3)
    with pytest.raises(ValueError, match="deadline"):
        Deadline(ms=0)
    with pytest.raises(ValueError, match="epochs or coord_ops"):
        EffortBudget()
    spec = QuerySpec(deadline=Deadline(ms=5.0))
    assert not spec.cacheable          # partial answers must not cache
    assert QuerySpec().cacheable


# ---------------------------------------------------------------------------
# autoscaling hints (satellite: ScalePolicy on synthetic load traces)
# ---------------------------------------------------------------------------


def _stats(queue=0, active=0, p95=None, replicas=1, shard_ops=None):
    from repro.api import ServeStats
    return ServeStats(replicas=replicas, shard_coord_ops=shard_ops,
                      plane_queue_depth=queue, plane_active=active,
                      plane_latency_p95_ms=p95)


def test_scale_policy_scales_out_on_sustained_queue():
    from repro.serve.scale import QueueDepthPolicy
    pol = QueueDepthPolicy(high_queue=8, sustain=3, cooldown=2)
    trace = [_stats(queue=q) for q in (12, 15, 11)]
    decisions = [pol.recommend(s) for s in trace]
    assert [d.action for d in decisions[:2]] == ["none", "none"]
    assert decisions[2].action == "add_replicas" and decisions[2].value == 2
    # cooldown holds, then a healthy queue resets the streak
    assert pol.recommend(_stats(queue=20)).action == "none"
    assert pol.recommend(_stats(queue=20)).action == "none"
    assert pol.recommend(_stats(queue=0)).action == "none"


def test_scale_policy_latency_slo_and_scale_in():
    from repro.serve.scale import QueueDepthPolicy
    pol = QueueDepthPolicy(high_queue=1000, p95_target_ms=50.0, sustain=2,
                           cooldown=0)
    assert pol.recommend(_stats(p95=80.0)).action == "none"
    d = pol.recommend(_stats(p95=90.0))
    assert d.action == "add_replicas" and d.value == 2
    # idle trace at 2 replicas scales back in
    pol2 = QueueDepthPolicy(sustain=2, cooldown=0)
    assert pol2.recommend(_stats(replicas=2)).action == "none"
    d2 = pol2.recommend(_stats(replicas=2))
    assert d2.action == "add_replicas" and d2.value == 1


def test_scale_policy_prefers_reshard_on_imbalance():
    from repro.serve.scale import QueueDepthPolicy
    pol = QueueDepthPolicy(high_queue=4, sustain=1, imbalance=2.0)
    d = pol.recommend(_stats(queue=9, shard_ops=[100.0, 0.0]))
    assert d.action == "reshard" and d.value == 4
    pol2 = QueueDepthPolicy(high_queue=4, sustain=1, imbalance=2.0)
    d2 = pol2.recommend(_stats(queue=9, shard_ops=[50.0, 50.0]))
    assert d2.action == "add_replicas"
