"""Fleet coverage (DESIGN.md §11): namespace lifecycle + LRU residency,
manifest recovery (payload + tuned sidecar + per-namespace quota),
shared-plane namespace isolation of the query cache, eviction → reload
bit-identity, the in-flight eviction guard, hot-namespace fairness on the
shared plane, placement planning, the fleet pressure policy, and the
crash-safe staged-directory checkpoint publish.

The two-sharded-namespaces-on-one-mesh case runs as a subprocess on a
forced 4-device host mesh (the test_distributed.py harness), covering
placement windows, post-reload bit-identity and the sharded crash-safe
save regardless of the parent's device count.
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.api import Index, ServeStats
from repro.configs.base import BMOConfig
from repro.fleet import (Fleet, FleetConfig, device_load, load_manifest,
                         plan_placement)
from repro.serve.plane import PlaneConfig, RequestPlane
from repro.serve.scale import FleetPressurePolicy, ScaleDecision, apply_fleet

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(prog: str, devices: int = 4, timeout: int = 560):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c",
                          "import repro\n" + textwrap.dedent(prog)],
                         capture_output=True, text=True, env=env,
                         cwd=ROOT, timeout=timeout)
    assert out.returncode == 0 and "OK" in out.stdout, \
        f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-3000:]}"


def _cfg(**kw):
    base = dict(k=4, delta=0.01, block=64, batch_arms=16, pulls_per_round=2,
                metric="l2")
    base.update(kw)
    return BMOConfig(**base)


def _corpus(n=160, d=128, seed=0):
    return np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32)


# ---------------------------------------------------------------------------
# lifecycle + LRU residency
# ---------------------------------------------------------------------------


def test_lifecycle_and_lru_residency(tmp_path):
    root = str(tmp_path / "fleet")
    fleet = Fleet(root, FleetConfig(max_resident=2))
    for i, name in enumerate(("a", "b", "c")):
        fleet.create(name, _corpus(seed=i), _cfg(), jax.random.PRNGKey(i))
    assert fleet.namespaces == ["a", "b", "c"]
    # third create pushed the LRU namespace out to its checkpoint
    assert fleet.resident_count == 2 and fleet.evicted_count == 1
    assert fleet.resident == ["b", "c"] and fleet.peek("a") is None
    assert os.path.isdir(os.path.join(root, "ns", "a"))   # durable from birth

    idx = fleet.get("a")                    # transparent reload
    assert idx.n_live == 160 and fleet.reload_count == 1
    assert fleet.resident_count == 2        # someone else made room
    assert "a" in fleet.resident

    with pytest.raises(ValueError, match="already exists"):
        fleet.create("a", _corpus(), _cfg())
    with pytest.raises(ValueError, match="bad namespace name"):
        fleet.create("no/slashes", _corpus(), _cfg())
    with pytest.raises(KeyError):
        fleet.get("nope")

    # the fleet-granularity scale actions execute against the live fleet
    assert apply_fleet(fleet, ScaleDecision("evict_namespace", target="a"))
    assert fleet.peek("a") is None
    assert not fleet.evict("a")             # already cold → refused
    assert apply_fleet(fleet, ScaleDecision("rebalance"))

    fleet.drop("b")
    assert "b" not in fleet and len(fleet) == 2
    assert not os.path.exists(os.path.join(root, "ns", "b"))


def test_open_recovers_manifest_with_sidecars(tmp_path):
    from repro.tune import TunedConfig
    root = str(tmp_path / "fleet")
    ids = np.arange(160, dtype=np.int32)
    fleet = Fleet(root, FleetConfig(max_resident=4))
    fleet.create("a", _corpus(seed=1), _cfg(), jax.random.PRNGKey(0),
                 payload=ids)
    fleet.create("b", _corpus(seed=2), _cfg(), jax.random.PRNGKey(1),
                 max_queue=3)
    t = TunedConfig(epoch_rounds=4, pulls_per_round=1, batch_arms=16)
    fleet.get("a")._apply_tuned(t)          # dirties the epoch
    assert fleet.flush() >= 1               # re-checkpoints the dirty ns

    fl2 = Fleet.open(root)
    assert fl2.namespaces == ["a", "b"]
    assert fl2.resident_count == 0          # lazy: nothing materialized yet
    assert fl2.namespace_max_queue("b") == 3
    assert fl2.namespace_max_queue("a") is None
    a2 = fl2.get("a")
    assert a2.tuned == t                    # tuned sidecar rode the reload
    np.testing.assert_array_equal(a2.payload, fleet.get("a").payload)

    doc = load_manifest(root)
    assert doc["version"] == 1 and sorted(doc["namespaces"]) == ["a", "b"]
    with pytest.raises(FileNotFoundError):
        Fleet.open(str(tmp_path / "not_a_fleet"))


# ---------------------------------------------------------------------------
# shared plane: cache isolation, bit-identical reload, fairness, guard
# ---------------------------------------------------------------------------


def test_namespace_cache_isolation_on_shared_plane(tmp_path):
    """Two namespaces holding IDENTICAL query vectors must never exchange
    cached rows; drop+recreate of the same name starts cold."""
    ca, cb = _corpus(seed=1), _corpus(seed=2)
    fleet = Fleet(str(tmp_path / "fleet"), FleetConfig(max_resident=4))
    fleet.create("a", ca, _cfg(), jax.random.PRNGKey(0))
    fleet.create("b", cb, _cfg(), jax.random.PRNGKey(0))
    plane = fleet.serve()
    q = ca[:2]

    ra = plane.query(q, rng=jax.random.PRNGKey(5), namespace="a")
    rb = plane.query(q, rng=jax.random.PRNGKey(5), namespace="b")
    # same bytes, different namespaces → each namespace's own answer
    assert not np.array_equal(ra.values, rb.values)
    ref = Index.build(cb, _cfg(), jax.random.PRNGKey(0)).query(
        q, jax.random.PRNGKey(5))
    assert rb.indices.tolist() == ref.indices.tolist()

    hits0 = fleet._cache.hits               # exact repeat within a ns hits
    ra2 = plane.query(q, rng=jax.random.PRNGKey(9), namespace="a")
    assert fleet._cache.hits >= hits0 + q.shape[0]
    assert ra2.indices.tolist() == ra.indices.tolist()

    # drop + recreate same name (different corpus) must start cold: no
    # stale hit may serve the OLD namespace's rows
    fleet.drop("a")
    fleet.create("a", cb, _cfg(), jax.random.PRNGKey(0))
    hits1 = fleet._cache.hits
    r3 = plane.query(q, rng=jax.random.PRNGKey(5), namespace="a")
    assert fleet._cache.hits == hits1       # cold, as required
    assert r3.indices.tolist() == ref.indices.tolist()


def test_evict_reload_bit_identical_topk(tmp_path):
    c = _corpus(seed=3)
    fleet = Fleet(str(tmp_path / "fleet"), FleetConfig(max_resident=2))
    fleet.create("x", c, _cfg(), jax.random.PRNGKey(0),
                 payload=np.arange(c.shape[0], dtype=np.int32))
    plane = fleet.serve()
    q = c[:3] + 0.01

    before = plane.query(q, rng=jax.random.PRNGKey(7), namespace="x",
                         cache="bypass")
    assert fleet.evict("x") and fleet.peek("x") is None
    after = plane.query(q, rng=jax.random.PRNGKey(7), namespace="x",
                        cache="bypass")    # transparent reload
    assert fleet.reload_count == 1 and fleet.eviction_count >= 1
    np.testing.assert_array_equal(before.indices, after.indices)
    np.testing.assert_array_equal(before.values, after.values)
    np.testing.assert_array_equal(
        fleet.get("x").payload[before.indices],
        fleet.get("x").payload[after.indices])

    st = plane.stats
    assert st.fleet_namespaces_resident == 1
    assert st.fleet_namespaces_evicted == 0
    assert st.fleet_reloads == 1
    assert st.ns_queue_depth == {}          # drained


def test_eviction_guard_refuses_inflight_namespace(tmp_path):
    c = _corpus(seed=4)
    fleet = Fleet(str(tmp_path / "fleet"), FleetConfig(max_resident=2))
    fleet.create("x", c, _cfg(), jax.random.PRNGKey(0))
    plane = fleet.serve()
    t = plane.submit(c[:2], rng=jax.random.PRNGKey(1), namespace="x",
                     cache="bypass")
    assert fleet.evict("x") is False        # in-flight ticket → refused
    with pytest.raises(RuntimeError, match="in-flight"):
        fleet.drop("x")
    plane.drain()
    assert t.result.terminal
    assert fleet.evict("x") is True         # quiesced → allowed


def test_hot_namespace_cannot_starve_cold(tmp_path):
    """Admission round-robins across (tenant, namespace) queues: a COLD
    namespace's single ticket rides the very next race group even while a
    hot namespace floods the plane — and its reload is transparent."""
    ca, cb = _corpus(seed=1), _corpus(seed=2)
    fleet = Fleet(str(tmp_path / "fleet"), FleetConfig(max_resident=2))
    fleet.create("hot", ca, _cfg(), jax.random.PRNGKey(0))
    fleet.create("cold", cb, _cfg(), jax.random.PRNGKey(1))
    plane = fleet.serve(PlaneConfig(max_group_queries=8,
                                    max_active_groups=2))
    assert fleet.evict("cold")              # make it actually cold

    heavy = [plane.submit(ca[:4] + i, tenant="t", namespace="hot",
                          rng=jax.random.PRNGKey(i), cache="bypass")
             for i in range(6)]
    cold = plane.submit(cb[:4], tenant="t", namespace="cold",
                        rng=jax.random.PRNGKey(99), cache="bypass")
    assert fleet.peek("cold") is not None   # reloaded at submit
    plane.step()
    # first admission round: one hot ticket + the cold ticket — the flood
    # cannot push the cold namespace past its fair slot
    assert cold.admitted_at is not None
    assert heavy[0].admitted_at is not None
    assert all(t.admitted_at is None for t in heavy[1:])
    plane.drain()
    assert cold.finished_at <= min(t.finished_at for t in heavy[1:])
    assert cold.result.reason == "certified"
    assert all(t.result.reason == "certified" for t in heavy)


def test_router_plane_requires_namespace(tmp_path):
    fleet = Fleet(str(tmp_path / "fleet"), FleetConfig(max_resident=2))
    fleet.create("x", _corpus(), _cfg(), jax.random.PRNGKey(0))
    plane = fleet.serve()
    with pytest.raises(ValueError):
        plane.submit(_corpus()[:2], rng=jax.random.PRNGKey(0))  # no ns
    with pytest.raises(KeyError):
        plane.submit(_corpus()[:2], rng=jax.random.PRNGKey(0),
                     namespace="ghost")
    with pytest.raises(ValueError):
        RequestPlane()                      # neither index nor router


def test_fleet_plane_default_namespace_enables_audit(tmp_path):
    """``fleet.serve(default=ns)`` binds that namespace's handle as the
    plane's default index AND hands the auditor the fleet router: every
    namespace's fully-certified traffic is δ-audited against its own
    ground truth, keyed per namespace in the summary."""
    fleet = Fleet(str(tmp_path / "fleet"), FleetConfig(max_resident=2))
    fleet.create("a", _corpus(seed=1), _cfg(), jax.random.PRNGKey(0))
    fleet.create("b", _corpus(seed=2), _cfg(), jax.random.PRNGKey(1))
    plane = fleet.serve(PlaneConfig(audit_rate=1.0), default="a")
    assert plane.auditor is not None and plane.index is fleet.peek("a")
    q = _corpus(seed=3)[:2]
    ra = plane.query(q, rng=jax.random.PRNGKey(5), namespace="a",
                     cache="bypass")
    r0 = plane.query(q, rng=jax.random.PRNGKey(5), cache="bypass")
    assert r0.indices.tolist() == ra.indices.tolist()  # routed to 'a'
    plane.query(q, rng=jax.random.PRNGKey(6), namespace="b", cache="bypass")
    plane.audit_flush()
    a = plane.auditor.summary()
    assert a["sampled_rows"] == 3 * q.shape[0]     # both 'a' AND the 'b'
    assert a["mismatch_rows"] == 0
    assert plane.auditor.skipped["namespaced"] == 0
    by_ns = {k["namespace"]: k for k in a["keys"]}
    assert by_ns[""]["sampled"] == q.shape[0]      # un-namespaced -> 'a'
    assert by_ns["a"]["sampled"] == q.shape[0]
    assert by_ns["b"]["sampled"] == q.shape[0]


def test_fleet_router_only_plane_audits_namespaces(tmp_path):
    """A router-only plane (no default index) still audits: namespaced
    tickets resolve their oracle index through the fleet at process time,
    and a namespace dropped before the oracle runs counts as unroutable
    instead of crashing or mis-auditing."""
    fleet = Fleet(str(tmp_path / "fleet"), FleetConfig(max_resident=2))
    fleet.create("a", _corpus(seed=1), _cfg(), jax.random.PRNGKey(0))
    fleet.create("b", _corpus(seed=2), _cfg(), jax.random.PRNGKey(1))
    plane = fleet.serve(PlaneConfig(audit_rate=1.0))
    assert plane.auditor is not None and plane.index is None
    q = _corpus(seed=3)[:2]
    plane.query(q, rng=jax.random.PRNGKey(5), namespace="a", cache="bypass")
    plane.query(q, rng=jax.random.PRNGKey(6), namespace="b", cache="bypass")
    fleet.drop("b")                       # ground truth for 'b' vanishes
    plane.audit_flush()
    a = plane.auditor.summary()
    assert a["sampled_rows"] == q.shape[0]            # only 'a' audited
    assert a["mismatch_rows"] == 0
    assert a["skipped"]["unroutable"] == 1            # the dropped 'b'
    assert [k["namespace"] for k in a["keys"]] == ["a"]


# ---------------------------------------------------------------------------
# placement + pressure policy
# ---------------------------------------------------------------------------


def test_placement_plan_deterministic_and_balanced():
    fp = {"big": (2, 1000), "s1": (1, 10), "s2": (1, 10)}
    plan = plan_placement(fp, 4)
    assert plan == plan_placement(fp, 4)    # deterministic
    assert plan["big"] == 0                 # heaviest first, lowest tie
    assert plan["s1"] != plan["big"] or plan["s1"] >= 2
    load = device_load(fp, plan, 4)
    assert load.max() == pytest.approx(500.0)   # smalls avoid big's window
    # a namespace spanning the whole mesh pins at offset 0
    assert plan_placement({"span": (8, 100)}, 4)["span"] == 0
    with pytest.raises(ValueError):
        plan_placement(fp, 0)


def test_fleet_pressure_policy_recommends_and_cools_down():
    pol = FleetPressurePolicy(high_queue=4, sustain=2, cooldown=1, skew=0.9)
    st = ServeStats(ns_queue_depth={"a": 5, "b": 1},
                    fleet_namespaces_resident=2)
    assert pol.recommend(st).action == "none"       # window 1 of 2
    d = pol.recommend(st)                            # sustained → act
    assert d.action == "evict_namespace" and d.target == "b"
    assert pol.recommend(st).reason == "cooldown"

    skewed = FleetPressurePolicy(high_queue=4, sustain=1, skew=0.5)
    d2 = skewed.recommend(ServeStats(ns_queue_depth={"a": 9, "b": 1}))
    assert d2.action == "rebalance" and d2.target == "a"
    # empty depth never trips
    idle = FleetPressurePolicy(sustain=1)
    assert idle.recommend(ServeStats()).action == "none"


# ---------------------------------------------------------------------------
# crash-safe checkpoint publish (satellite: kill the write midway)
# ---------------------------------------------------------------------------


def test_crash_mid_save_preserves_previous_checkpoint(tmp_path, monkeypatch):
    """Kill the save after the arrays are written but before the payload
    sidecar lands: the destination must still hold the COMPLETE previous
    checkpoint (all-or-nothing publish), with no tmp residue."""
    c = _corpus(seed=5)
    ids = np.arange(c.shape[0], dtype=np.int32)
    idx = Index.build(c, _cfg(), jax.random.PRNGKey(0), payload=ids)
    path = str(tmp_path / "idx")
    idx.save(path)
    q = c[:2]
    want = Index.load(path).query(q, jax.random.PRNGKey(3))
    n_before = idx.n_live

    idx.insert(c[:8] + 5.0, payload=ids[:8])
    real_save = np.save

    def boom(file, arr, *a, **kw):
        if str(file).endswith("payload.npy"):
            raise OSError("disk died mid-write")
        return real_save(file, arr, *a, **kw)

    monkeypatch.setattr("repro.api.handle.np.save", boom)
    with pytest.raises(OSError, match="mid-write"):
        idx.save(path)
    monkeypatch.undo()

    assert not [p for p in os.listdir(tmp_path) if ".tmp-" in p]
    again = Index.load(path)
    assert again.n_live == n_before         # old checkpoint, fully intact
    got = again.query(q, jax.random.PRNGKey(3))
    np.testing.assert_array_equal(got.indices, want.indices)
    np.testing.assert_array_equal(again.payload[:ids.shape[0]], ids)

    # a stale tmp sibling from a dead writer is ignored by load and does
    # not block the next successful publish
    os.makedirs(path + ".tmp-99999")
    with open(os.path.join(path + ".tmp-99999", "junk"), "w") as f:
        f.write("partial")
    idx.save(path)
    assert Index.load(path).n_live == n_before + 8


# ---------------------------------------------------------------------------
# two sharded namespaces on one 4-device mesh (subprocess)
# ---------------------------------------------------------------------------


def test_two_sharded_namespaces_on_one_mesh_subprocess():
    _run("""
    import os, tempfile
    import numpy as np, jax
    from repro.api import Index
    from repro.configs.base import BMOConfig
    from repro.fleet import Fleet, FleetConfig
    import repro.checkpoint.manager as mgr

    cfg = BMOConfig(k=4, delta=0.01, block=64, batch_arms=16,
                    pulls_per_round=2, metric="l2")
    r = np.random.default_rng(0)
    A = r.normal(size=(256, 128)).astype(np.float32)
    B = r.normal(size=(320, 128)).astype(np.float32)
    root = tempfile.mkdtemp(prefix="bmo_fleet_") + "/fleet"
    fleet = Fleet(root, FleetConfig(max_resident=2))
    fleet.create("a", A, cfg, jax.random.PRNGKey(1), shards=2)
    fleet.create("b", B, cfg, jax.random.PRNGKey(2), shards=2)

    # placement: two S=2 namespaces pack into disjoint device windows
    plan = fleet.rebalance(4)
    assert sorted(plan.values()) == [0, 2], plan
    offs = {n: fleet.get(n).store.device_offset for n in ("a", "b")}
    assert offs == plan, (offs, plan)

    plane = fleet.serve()
    qa = A[:3] + 0.01
    ra = plane.query(qa, rng=jax.random.PRNGKey(5), namespace="a",
                     cache="bypass")
    rb = plane.query(B[:3] + 0.01, rng=jax.random.PRNGKey(6),
                     namespace="b", cache="bypass")
    assert ra.reason == "certified" and rb.reason == "certified"
    ref = Index.build(A, cfg, jax.random.PRNGKey(1), shards=2).query(
        qa, jax.random.PRNGKey(5))
    assert ra.indices.tolist() == ref.indices.tolist()

    # evict + reload of a SHARDED namespace: bit-identical and the planned
    # device window is re-applied to the fresh handle
    assert fleet.evict("a")
    ra2 = plane.query(qa, rng=jax.random.PRNGKey(5), namespace="a",
                      cache="bypass")
    assert ra2.indices.tolist() == ra.indices.tolist()
    assert fleet.get("a").store.device_offset == plan["a"]

    # crash-safe sharded save: die after one shard is staged — the
    # previous checkpoint must survive whole
    idx = fleet.get("b")
    idx.insert(B[:4] + 9.0)
    calls = {"n": 0}
    real = mgr.save
    def boom(p, state, **kw):
        calls["n"] += 1
        if calls["n"] == 2:
            raise OSError("killed mid-save")
        return real(p, state, **kw)
    mgr.save = boom
    try:
        idx.save(os.path.join(root, "ns", "b"))
        raise SystemExit("save should have died")
    except OSError:
        pass
    mgr.save = real
    assert not [p for p in os.listdir(os.path.join(root, "ns"))
                if ".tmp-" in p]
    old = Index.load(os.path.join(root, "ns", "b"))
    assert old.n_live == 320, old.n_live     # pre-insert checkpoint intact

    st = plane.stats
    assert st.fleet_namespaces_resident == 2 and st.fleet_reloads >= 1
    print("OK")
    """, devices=4)
