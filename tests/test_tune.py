"""repro.tune — the self-racing autotuner (DESIGN.md §9).

Covers the signature scheme, the candidate grid + roofline pruning, the
successive-halving measurement race, the Index.tune() admin op, the
tuned.json sidecar round trip with strict signature-drift fallback, the
per-query ``use_tuned`` opt-out, and the deadline-aware fused-round cap
the tuned cost estimates enable.
"""
import dataclasses
import json
import os

import jax
import numpy as np
import pytest

from repro.api import Index, QuerySpec
from repro.configs.base import BMOConfig
from repro.data.synthetic import make_knn_benchmark_data
from repro.tune import (TUNED_FILE, TunedConfig, cache_clear, cache_get,
                        candidate_grid, load_tuned, save_tuned,
                        seed_candidates, signature_of, tune_store,
                        tuned_mode)


@pytest.fixture(autouse=True)
def _fresh_tune_cache():
    cache_clear()
    yield
    cache_clear()


def _cfg(**kw):
    kw.setdefault("k", 3)
    kw.setdefault("delta", 0.01)
    kw.setdefault("batch_arms", 16)
    kw.setdefault("pulls_per_round", 2)
    return BMOConfig(**kw)


def _store(n=256, d=256, seed=0, **kw):
    corpus, queries = make_knn_benchmark_data("dense", n, d, 4, seed=seed)
    from repro.index.builder import build_index
    return build_index(corpus, _cfg(**kw), jax.random.PRNGKey(0)), queries


# ---------------------------------------------------------------------------
# signature
# ---------------------------------------------------------------------------


def test_signature_fields_and_pow2_bucketing():
    store, _ = _store(n=200)
    sig = signature_of(store, backend="cpu")
    assert sig.n_bucket == 256            # next_pow2(200)
    assert sig.kind == "dense" and sig.shards == 1
    assert sig.d == store.d and sig.block == store.block
    # round-trips through its dict form (the sidecar encoding)
    from repro.tune import StoreSignature
    assert StoreSignature.from_dict(sig.to_dict()) == sig


def test_signature_is_insert_stable_within_bucket():
    from repro.index import mutable
    store, _ = _store(n=200)
    grown, _gids = mutable.insert(store, np.zeros((10, store.d), np.float32))
    assert signature_of(grown, "cpu") == signature_of(store, "cpu")
    # ...but crossing the pow2 bucket changes it
    big, _gids = mutable.insert(
        store, np.zeros((100, store.d), np.float32))
    assert signature_of(big, "cpu") != signature_of(store, "cpu")


# ---------------------------------------------------------------------------
# candidates + roofline seed
# ---------------------------------------------------------------------------


def test_candidate_grid_identity_first_and_deduped():
    store, _ = _store()
    cands = candidate_grid(store, backend="cpu")
    assert cands[0] == TunedConfig.from_cfg(store.cfg)
    keys = [dataclasses.astuple(dataclasses.replace(
        c, epoch_ms=0.0, round_ms=0.0)) for c in cands]
    assert len(keys) == len(set(keys))
    assert any(c.mode == "rounds" for c in cands)       # fallback arm
    assert all(c.batch_arms <= store.n_live for c in cands)


def test_tuned_config_bind_touches_only_perf_knobs():
    cfg = _cfg(epoch_rounds=2)
    t = TunedConfig(epoch_rounds=8, pulls_per_round=1, batch_arms=64,
                    frontier_floor=128, kernel_buffers=4)
    bound = t.bind(cfg)
    assert (bound.epoch_rounds, bound.pulls_per_round,
            bound.batch_arms) == (8, 1, 64)
    assert bound.frontier_floor == 128 and bound.kernel_buffers == 4
    # certification contract untouched
    assert (bound.k, bound.delta, bound.metric) == \
        (cfg.k, cfg.delta, cfg.metric)
    assert TunedConfig.from_dict(t.to_dict()) == t


def test_seed_candidates_prunes_but_keeps_identity():
    store, _ = _store()
    cands = candidate_grid(store, backend="cpu")
    survivors, report = seed_candidates(store, cands, max_candidates=4)
    assert survivors[0] == cands[0]       # identity never pruned
    assert 1 <= len(survivors) <= 4
    assert len(report) == len(cands)
    scored = [r["e"] for r in report if r["e"] is not None]
    assert scored and all(e > 0 for e in scored)


def test_tuned_mode_resolution():
    t = TunedConfig(epoch_rounds=2, pulls_per_round=2, batch_arms=16,
                    mode="rounds")
    assert tuned_mode(t, "auto") == "rounds"
    assert tuned_mode(t, "fused") == "fused"    # explicit spec mode wins
    assert tuned_mode(None, "auto") == "auto"


# ---------------------------------------------------------------------------
# tune_store + in-process cache
# ---------------------------------------------------------------------------


def test_tune_store_winner_and_cache():
    store, queries = _store()
    tuned, report = tune_store(store, queries, jax.random.PRNGKey(0),
                               levels=1, max_candidates=2)
    assert not report["cached"]
    assert report["winner_median_ms"] <= report["default_median_ms"] + 1e-9
    assert tuned.round_ms > 0.0           # the deadline planner's basis
    assert cache_get(signature_of(store)) == tuned
    # equal-signature re-tune is a cache hit, no re-race
    again, rep2 = tune_store(store, queries, jax.random.PRNGKey(1))
    assert rep2["cached"] and again == tuned


def test_tune_store_sparse_requires_queries():
    from repro.data.synthetic import clustered_sparse
    from repro.index.builder import build_index
    corpus = clustered_sparse(64, 512, seed=1)
    cfg = _cfg(block=1, pulls_per_round=8, init_pulls=16, metric="l1",
               sparse=True)
    store = build_index(corpus, cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="sparse"):
        tune_store(store, None, jax.random.PRNGKey(0))
    # sparse grid is per-round only
    cands = candidate_grid(store, backend="cpu")
    assert all(c.mode in ("auto", "rounds") for c in cands)


# ---------------------------------------------------------------------------
# Index.tune + sidecar round trip
# ---------------------------------------------------------------------------


def _built_index(n=256, d=256, **kw):
    corpus, queries = make_knn_benchmark_data("dense", n, d, 4, seed=2)
    return Index.build(corpus, _cfg(**kw), jax.random.PRNGKey(0)), queries


def test_index_tune_applies_under_epoch_fence():
    idx, queries = _built_index()
    base = np.sort(np.asarray(
        idx.query(queries, jax.random.PRNGKey(0)).indices))
    e0 = idx.epoch
    report = idx.tune(queries, jax.random.PRNGKey(0), levels=1,
                      max_candidates=2)
    assert report["applied"] and idx.tuned is not None
    assert idx.epoch == e0 + 1            # installed through the fence
    # tuning changes cost, never results: δ-PAC exactness is preserved
    got = np.sort(np.asarray(
        idx.query(queries, jax.random.PRNGKey(0)).indices))
    assert np.array_equal(got, base)
    # served config now carries the tuned knobs
    assert idx.cfg == idx.tuned.bind(idx._base_cfg)


def test_index_tune_apply_false_measures_only():
    idx, queries = _built_index()
    e0 = idx.epoch
    report = idx.tune(queries, jax.random.PRNGKey(0), levels=1,
                      max_candidates=2, apply=False)
    assert not report["applied"]
    assert idx.tuned is None and idx.epoch == e0


def test_use_tuned_opt_out_races_build_config():
    idx, queries = _built_index()
    idx.tune(queries, jax.random.PRNGKey(0), levels=1, max_candidates=2)
    spec = QuerySpec(use_tuned=False)
    assert not spec.cacheable             # opt-out bypasses the LRU
    res = idx.query(queries, jax.random.PRNGKey(0), spec=spec)
    exact = np.sort(np.asarray(
        idx.query(queries, jax.random.PRNGKey(1)).indices))
    assert np.array_equal(np.sort(np.asarray(res.indices)), exact)


def test_sidecar_roundtrip_and_signature_drift(tmp_path):
    idx, queries = _built_index()
    base = np.sort(np.asarray(
        idx.query(queries, jax.random.PRNGKey(0)).indices))
    idx.tune(queries, jax.random.PRNGKey(0), levels=1, max_candidates=2)
    path = str(tmp_path / "ckpt")
    idx.save(path)
    assert os.path.exists(os.path.join(path, TUNED_FILE))

    cache_clear()                          # force the sidecar path
    idx2 = Index.load(path)
    assert idx2.tuned == idx.tuned         # serves tuned with NO re-tune
    got = np.sort(np.asarray(
        idx2.query(queries, jax.random.PRNGKey(0)).indices))
    assert np.array_equal(got, base)
    # accepted sidecar also primes the in-process cache
    assert cache_get(signature_of(idx2.store)) == idx.tuned

    # drifted signature → bit-compatible fallback to build defaults
    fpath = os.path.join(path, TUNED_FILE)
    doc = json.load(open(fpath))
    doc["signature"]["n_bucket"] *= 2
    json.dump(doc, open(fpath, "w"))
    cache_clear()
    idx3 = Index.load(path)
    assert idx3.tuned is None
    assert idx3.cfg == idx._base_cfg

    # stale version → same fallback
    doc = json.load(open(fpath))
    doc["version"] = 999
    json.dump(doc, open(fpath, "w"))
    cache_clear()
    assert Index.load(path).tuned is None

    # unreadable file → same fallback
    with open(fpath, "w") as f:
        f.write("{not json")
    cache_clear()
    assert Index.load(path).tuned is None


def test_missing_sidecar_is_silent_default(tmp_path):
    idx, _ = _built_index()
    path = str(tmp_path / "plain")
    idx.save(path)                         # never tuned → no sidecar
    assert not os.path.exists(os.path.join(path, TUNED_FILE))
    assert Index.load(path).tuned is None
    tuned, why = load_tuned(path, idx.store)
    assert tuned is None and why == "missing"


def test_save_tuned_explicit_roundtrip(tmp_path):
    store, _ = _store()
    sig = signature_of(store)
    t = TunedConfig(epoch_rounds=4, pulls_per_round=1, batch_arms=32,
                    round_ms=1.5, epoch_ms=6.0)
    save_tuned(str(tmp_path), sig, t, measured={"round_ms": 1.5})
    got, why = load_tuned(str(tmp_path), store)
    assert why == "ok" and got == t


# ---------------------------------------------------------------------------
# deadline-aware fused-round selection (DESIGN.md §9.7)
# ---------------------------------------------------------------------------


def test_deadline_caps_fused_rounds_on_the_pow2_chain():
    from repro.index.anytime import make_session
    store, queries = _store()
    sess = make_session(store, queries, jax.random.PRNGKey(0),
                        cfg=store.cfg)
    R0, R_cap = sess._R0, sess._R_cap
    # no deadline → identity
    assert sess._deadline_R(R_cap) == R_cap
    # huge budget → uncapped
    sess.set_deadline(1e6, round_ms=1.0)
    assert sess._deadline_R(R_cap) == R_cap
    # tight budget → floor of the chain, never below R0
    sess.set_deadline(0.01, round_ms=50.0)
    assert sess._deadline_R(R_cap) == min(R_cap, R0)
    # mid budget lands ON the R0·2^j chain (a warm compile point)
    sess.set_deadline(100.0, round_ms=1.0)
    r = sess._deadline_R(1 << 20)
    assert r >= R0 and (r % R0 == 0)
    assert (r // R0) & ((r // R0) - 1) == 0   # pow2 multiplier
    # zero round estimate (untuned) → rule disabled
    sess.set_deadline(0.01, round_ms=0.0)
    assert sess._deadline_R(R_cap) == R_cap


def test_race_deadline_ms_still_certifies():
    idx, queries = _built_index()
    idx.tune(queries, jax.random.PRNGKey(0), levels=1, max_candidates=2)
    sess = idx.race(queries, jax.random.PRNGKey(0), deadline_ms=1e6)
    while sess.step():
        pass
    snap = sess.snapshot
    assert np.asarray(snap.done).all()
    exact = np.sort(np.asarray(
        idx.query(queries, jax.random.PRNGKey(1)).indices))
    assert np.array_equal(np.sort(np.asarray(snap.ids)), exact)
