"""Fault-tolerance: injected failure mid-training → restart from checkpoint →
final state bit-identical to an uninterrupted run (deterministic pipeline)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import TrainConfig, get_arch
from repro.data.loader import ShardedLoader
from repro.models import build_model
from repro.runtime.supervisor import FailureInjector, Supervisor
from repro.train.steps import init_train_state, make_train_step


def _setup(tmp_path, tag, fail_at=None):
    entry = get_arch("xlstm-350m")
    cfg = entry.smoke
    model = build_model(cfg)
    plan = dataclasses.replace(entry.plan, fsdp=False, tp=False, sp=False,
                               grad_accum=1, param_dtype="float32")
    tcfg = TrainConfig(total_steps=24, lr=1e-3, warmup_steps=2)
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    step, _ = make_train_step(model, plan, tcfg, mesh)
    jstep = jax.jit(step, donate_argnums=0)
    loader = ShardedLoader(cfg.vocab_size, 4, 32, seed=7)
    ckpt = CheckpointManager(str(tmp_path / tag), keep=3, async_save=False)
    sup = Supervisor(
        ckpt=ckpt, train_step=jstep, loader=loader.get,
        init_state=lambda: init_train_state(model, plan, tcfg,
                                            jax.random.PRNGKey(0)),
        ckpt_every=8,
        injector=FailureInjector([fail_at]) if fail_at else None,
    )
    return sup


def test_restart_equals_uninterrupted(tmp_path):
    clean = _setup(tmp_path, "clean").run(24)
    faulty = _setup(tmp_path, "faulty", fail_at=13).run(24)
    for a, b in zip(jax.tree_util.tree_leaves(clean["params"]),
                    jax.tree_util.tree_leaves(faulty["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)
    assert int(clean["step"]) == int(faulty["step"]) == 24


def test_multiple_failures(tmp_path):
    sup = _setup(tmp_path, "multi")
    sup.injector = FailureInjector([10, 18, 20])
    state = sup.run(24)
    assert int(state["step"]) == 24


def test_too_many_failures_raises(tmp_path):
    sup = _setup(tmp_path, "fatal")
    sup.max_failures = 1
    sup.injector = FailureInjector([2, 3, 4])
    import pytest
    with pytest.raises(RuntimeError):
        sup.run(24)


def test_straggler_watchdog_flags_slow_steps():
    import time
    from repro.runtime.straggler import StragglerWatchdog
    wd = StragglerWatchdog(window=50, p95_factor=2.0)
    for step in range(15):
        wd.start()
        time.sleep(0.001 if step != 12 else 0.05)
        wd.stop(step)
    assert any(s == 12 for s, _, _ in wd.flagged)
