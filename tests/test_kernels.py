"""Per-kernel validation: Pallas (interpret mode = kernel body on CPU)
against the pure-jnp ref.py oracles, swept over shapes and dtypes."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# FWHT
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("d", [2, 8, 64, 256, 1024])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fwht_matches_ref(rng, d, dtype):
    x = jnp.asarray(rng.normal(size=(5, d)).astype(np.float32)).astype(dtype)
    got = ops.fwht(x, impl="interpret")
    want = ref.fwht_ref(x)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


def test_fwht_matches_explicit_hadamard(rng):
    d = 32
    H = np.array([[1.0]])
    while H.shape[0] < d:
        H = np.block([[H, H], [H, -H]])
    H = H / np.sqrt(d)
    x = rng.normal(size=(7, d)).astype(np.float32)
    got = np.asarray(ops.fwht(jnp.asarray(x), impl="interpret"))
    np.testing.assert_allclose(got, x @ H.T, atol=1e-5)


def test_fwht_preserves_l2_distances(rng):
    x = jnp.asarray(rng.normal(size=(6, 128)).astype(np.float32))
    y = ops.fwht(x, impl="interpret")
    dx = np.asarray(ref.pairwise_dist_ref(x, x))
    dy = np.asarray(ref.pairwise_dist_ref(y, y))
    np.testing.assert_allclose(dx, dy, atol=1e-3, rtol=1e-4)


def test_fwht_row_padding(rng):
    """n not divisible by the row block."""
    x = jnp.asarray(rng.normal(size=(3, 64)).astype(np.float32))
    got = ops.fwht(x, impl="interpret")
    want = ref.fwht_ref(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


# ---------------------------------------------------------------------------
# block_pull
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,d,block,B,P", [
    (16, 256, 128, 4, 2),
    (32, 512, 64, 8, 3),
    (8, 1024, 256, 8, 1),
    (64, 384, 128, 16, 5),
])
@pytest.mark.parametrize("metric", ["l2", "l1"])
def test_block_pull_matches_ref(rng, n, d, block, B, P, metric):
    X = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(d,)).astype(np.float32))
    arm = jnp.asarray(rng.integers(0, n, B), jnp.int32)
    blk = jnp.asarray(rng.integers(0, d // block, (B, P)), jnp.int32)
    got = ops.block_pull(X, q, arm, blk, block=block, metric=metric, impl="interpret")
    want = ops.block_pull(X, q, arm, blk, block=block, metric=metric, impl="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_pull_dtypes(rng, dtype):
    X = jnp.asarray(rng.normal(size=(8, 256)).astype(np.float32)).astype(dtype)
    q = jnp.asarray(rng.normal(size=(256,)).astype(np.float32)).astype(dtype)
    arm = jnp.arange(4, dtype=jnp.int32)
    blk = jnp.zeros((4, 2), jnp.int32)
    got = ops.block_pull(X, q, arm, blk, block=128, metric="l2", impl="interpret")
    want = ops.block_pull(X, q, arm, blk, block=128, metric="l2", impl="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("Q,n,d,block,B,P", [
    (3, 16, 256, 128, 4, 2),
    (5, 32, 512, 64, 8, 3),
    (2, 8, 1024, 256, 8, 1),
])
@pytest.mark.parametrize("metric", ["l2", "l1"])
def test_block_pull_multi_matches_ref(rng, Q, n, d, block, B, P, metric):
    X = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    qs = jnp.asarray(rng.normal(size=(Q, d)).astype(np.float32))
    arm = jnp.asarray(rng.integers(0, n, (Q, B)), jnp.int32)
    blk = jnp.asarray(rng.integers(0, d // block, (Q, B, P)), jnp.int32)
    got = ops.block_pull_multi(X, qs, arm, blk, block=block, metric=metric,
                               impl="interpret")
    want = ops.block_pull_multi(X, qs, arm, blk, block=block, metric=metric,
                                impl="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)
    # row q of the multi-query pull == the single-query pull for that query
    for qidx in range(Q):
        single = ops.block_pull(X, qs[qidx], arm[qidx], blk[qidx],
                                block=block, metric=metric, impl="ref")
        np.testing.assert_allclose(np.asarray(got[qidx]), np.asarray(single),
                                   rtol=1e-5)


def test_block_pull_full_coverage_equals_exact(rng):
    """Pulling every block once averages to the exact θ."""
    n, d, block = 6, 512, 128
    X = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(d,)).astype(np.float32))
    nb = d // block
    blk = jnp.broadcast_to(jnp.arange(nb)[None], (n, nb)).astype(jnp.int32)
    pulls = ops.block_pull(X, q, jnp.arange(n, dtype=jnp.int32), blk,
                           block=block, metric="l2", impl="interpret")
    theta = np.asarray(ref.pairwise_dist_ref(q[None], X))[0] / d
    np.testing.assert_allclose(np.asarray(pulls).mean(1), theta, rtol=1e-4)


# ---------------------------------------------------------------------------
# fused_epoch_pull (round-fused racing kernel, DESIGN.md §4)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("Q,n,d,block,B,T", [
    (3, 16, 256, 128, 4, 6),     # T = R·P for (R, P) = (3, 2)
    (5, 32, 512, 64, 8, 2),      # single-round epoch (R = 1)
    (2, 8, 1024, 256, 6, 12),
    (4, 64, 384, 128, 16, 9),    # odd T, d_pad not a power of two
])
@pytest.mark.parametrize("metric", ["l2", "l1"])
def test_fused_epoch_pull_matches_ref(rng, Q, n, d, block, B, T, metric):
    X = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    qs = jnp.asarray(rng.normal(size=(Q, d)).astype(np.float32))
    arm = jnp.asarray(rng.integers(0, n, (Q, B)), jnp.int32)
    blk = jnp.asarray(rng.integers(0, d // block, (Q, B, T)), jnp.int32)
    got = ops.fused_epoch_pull(X, qs, arm, blk, block=block, metric=metric,
                               impl="interpret")
    want = ops.fused_epoch_pull(X, qs, arm, blk, block=block, metric=metric,
                                impl="ref")
    assert got.shape == (Q, B, 2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=1e-5)


def test_fused_epoch_pull_stats_match_raw_pulls(rng):
    """The kernel's on-chip (mean, M2) reduction over T pulls must merge
    into running state exactly like feeding the T raw per-round pull values
    through the per-round Welford update."""
    from repro.core import confidence as conf
    Q, n, d, block, B, T = 2, 16, 512, 64, 4, 8
    X = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    qs = jnp.asarray(rng.normal(size=(Q, d)).astype(np.float32))
    arm = jnp.asarray(rng.integers(0, n, (Q, B)), jnp.int32)
    blk = jnp.asarray(rng.integers(0, d // block, (Q, B, T)), jnp.int32)
    raw = ops.block_pull_multi(X, qs, arm, blk, block=block, impl="ref")
    stats = ops.fused_epoch_pull(X, qs, arm, blk, block=block,
                                 impl="interpret")

    mean0 = jnp.asarray(rng.normal(size=(Q * B,)).astype(np.float32))
    count0 = jnp.asarray(rng.integers(2, 10, (Q * B,)).astype(np.float32))
    m20 = jnp.abs(jnp.asarray(rng.normal(size=(Q * B,)).astype(np.float32)))
    mask = jnp.ones((Q * B,), jnp.float32)
    want = conf.welford_batch_update(mean0, count0, m20,
                                     raw.reshape(Q * B, T), mask)
    got = conf.welford_merge(mean0, count0, m20,
                             stats[..., 0].reshape(-1), float(T),
                             stats[..., 1].reshape(-1), mask)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=2e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# pairwise_dist
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("Q,n,d", [(4, 16, 64), (9, 50, 300), (8, 128, 512),
                                   (1, 7, 1000)])
@pytest.mark.parametrize("metric", ["l2", "l1"])
def test_pairwise_matches_ref(rng, Q, n, d, metric):
    qs = jnp.asarray(rng.normal(size=(Q, d)).astype(np.float32))
    X = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    got = ops.pairwise_dist(qs, X, metric=metric, impl="interpret")
    want = ops.pairwise_dist(qs, X, metric=metric, impl="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


def test_pairwise_l2_dot_variant(rng):
    """The MXU (−2qxᵀ + norms) form agrees with the elementwise form."""
    from repro.kernels.pairwise_dist import pairwise_dist_pallas
    qs = jnp.asarray(rng.normal(size=(8, 256)).astype(np.float32))
    X = jnp.asarray(rng.normal(size=(32, 256)).astype(np.float32))
    a = pairwise_dist_pallas(qs, X, metric="l2", interpret=True)
    b = pairwise_dist_pallas(qs, X, metric="l2_dot", interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-3)


def test_pairwise_zero_distance(rng):
    X = jnp.asarray(rng.normal(size=(5, 128)).astype(np.float32))
    d = np.asarray(ops.pairwise_dist(X, X, metric="l2", impl="interpret"))
    np.testing.assert_allclose(np.diag(d), 0.0, atol=1e-3)
