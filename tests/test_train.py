"""Training-substrate tests: grad-accumulation equivalence, optimizers,
clipping, schedules, loss."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import TrainConfig, get_arch
from repro.models import build_model
from repro.optim import adafactor, adamw, make_optimizer, warmup_cosine
from repro.optim.compress import clip_by_global_norm, global_norm
from repro.train.loss import cross_entropy
from repro.train.steps import init_train_state, make_train_step


def _mesh():
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def test_grad_accum_equivalence(rng):
    """ga=1 and ga=4 must produce (numerically) the same update."""
    entry = get_arch("qwen2.5-14b")
    model = build_model(entry.smoke)
    tcfg = TrainConfig(total_steps=4, lr=1e-3)
    batch = {"tokens": jnp.asarray(rng.integers(0, 256, (8, 16)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, 256, (8, 16)), jnp.int32)}
    outs = []
    for ga in (1, 4):
        plan = dataclasses.replace(entry.plan, fsdp=False, tp=False, sp=False,
                                   grad_accum=ga, param_dtype="float32")
        state = init_train_state(model, plan, tcfg, jax.random.PRNGKey(0))
        step, _ = make_train_step(model, plan, tcfg, _mesh())
        new_state, m = jax.jit(step)(state, batch)
        outs.append(new_state["params"])
    flat1 = jax.tree_util.tree_leaves(outs[0])
    flat4 = jax.tree_util.tree_leaves(outs[1])
    for a, b in zip(flat1, flat4):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-5)


def test_adamw_converges_quadratic():
    opt = adamw(weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    for i in range(200):
        grads = {"w": 2 * params["w"]}
        params, state = opt.update(grads, state, params, jnp.asarray(i), 0.1)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adafactor_converges_quadratic():
    opt = adafactor()
    params = {"w": jnp.ones((4, 3)) * 3.0}
    state = opt.init(params)
    for i in range(300):
        grads = {"w": 2 * params["w"]}
        params, state = opt.update(grads, state, params, jnp.asarray(i), 0.05)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_adafactor_state_is_factored():
    opt = adafactor()
    params = {"w": jnp.zeros((8, 16)), "b": jnp.zeros((16,))}
    st = opt.init(params)
    assert st["w"]["r"].shape == (8,)
    assert st["w"]["c"].shape == (16,)
    assert st["b"]["v"].shape == (16,)


def test_clip_by_global_norm():
    g = {"a": jnp.asarray([3.0, 4.0])}           # norm 5
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(5.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    # under the cap → untouched
    clipped2, _ = clip_by_global_norm(g, 10.0)
    np.testing.assert_allclose(np.asarray(clipped2["a"]), [3.0, 4.0])


def test_warmup_cosine_schedule():
    s = warmup_cosine(1.0, 10, 100)
    assert float(s(0)) == 0.0
    assert float(s(10)) == pytest.approx(1.0, rel=1e-2)
    assert float(s(100)) == pytest.approx(0.1, rel=1e-2)
    assert float(s(55)) < float(s(20))


def test_cross_entropy_matches_naive(rng):
    logits = jnp.asarray(rng.normal(size=(2, 5, 11)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, 11, (2, 5)), jnp.int32)
    loss, n = cross_entropy(logits, labels)
    lf = np.asarray(logits)
    p = np.exp(lf - lf.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    want = -np.log(p[np.arange(2)[:, None], np.arange(5)[None], np.asarray(labels)])
    assert float(loss) == pytest.approx(want.mean(), rel=1e-5)
    assert float(n) == 10


def test_cross_entropy_ignore_index(rng):
    logits = jnp.asarray(rng.normal(size=(1, 4, 7)).astype(np.float32))
    labels = jnp.asarray([[1, -100, 3, -100]], jnp.int32)
    loss, n = cross_entropy(logits, labels)
    assert float(n) == 2


def test_quantize_error_feedback_bound(rng):
    from repro.optim.compress import _quantize
    g = jnp.asarray(rng.normal(size=(100,)).astype(np.float32) * 7)
    q, scale = _quantize(g)
    err = np.abs(np.asarray(g) - np.asarray(q, np.float32) * float(scale))
    assert err.max() <= float(scale) / 2 + 1e-6
