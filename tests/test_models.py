"""Per-arch smoke tests (reduced configs, CPU): forward + one train step,
asserting output shapes and finiteness — required for every assigned arch."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, TrainConfig, get_arch
from repro.models import build_model
from repro.sharding.spec import init_params
from repro.train.steps import init_train_state, make_train_step


def _batch(cfg, rng, B=2, S=32):
    if cfg.family == "vlm":
        return {"embeds": jnp.asarray(rng.normal(size=(B, S, cfg.d_model)),
                                      jnp.float32).astype(jnp.bfloat16),
                "positions3": jnp.broadcast_to(jnp.arange(S)[None, None],
                                               (3, B, S)).astype(jnp.int32),
                "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                      jnp.int32)}
    if cfg.family == "audio":
        sd = max(S // cfg.dec_seq_div, 4)
        return {"frames": jnp.asarray(rng.normal(size=(B, S, cfg.d_model)),
                                      jnp.float32).astype(jnp.bfloat16),
                "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, sd)),
                                      jnp.int32),
                "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, sd)),
                                      jnp.int32)}
    return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                  jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                  jnp.int32)}


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch, rng):
    entry = get_arch(arch)
    cfg = entry.smoke
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0))
    batch = _batch(cfg, rng)
    out = model.apply(params, batch, remat="none")
    logits = out[0]
    B = batch.get("tokens", batch.get("embeds")).shape[0]
    assert logits.shape[0] == B and logits.shape[-1] == cfg.vocab_size
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch, rng):
    entry = get_arch(arch)
    cfg = entry.smoke
    model = build_model(cfg)
    plan = dataclasses.replace(entry.plan, fsdp=False, tp=False, sp=False,
                               ep=False, grad_accum=1, param_dtype="float32")
    tcfg = TrainConfig(total_steps=4)
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    state = init_train_state(model, plan, tcfg, jax.random.PRNGKey(0))
    step, _ = make_train_step(model, plan, tcfg, mesh)
    jstep = jax.jit(step, donate_argnums=0)
    batch = _batch(cfg, rng)
    state, m = jstep(state, batch)
    assert np.isfinite(float(m["loss"]))
    assert int(state["step"]) == 1


@pytest.mark.parametrize("arch", ["qwen2.5-14b", "xlstm-350m", "zamba2-2.7b",
                                  "deepseek-v3-671b"])
def test_loss_decreases(arch, rng):
    entry = get_arch(arch)
    cfg = entry.smoke
    model = build_model(cfg)
    plan = dataclasses.replace(entry.plan, fsdp=False, tp=False, sp=False,
                               ep=False, grad_accum=2, param_dtype="float32")
    tcfg = TrainConfig(total_steps=8, lr=1e-3, warmup_steps=1)
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    state = init_train_state(model, plan, tcfg, jax.random.PRNGKey(0))
    step, _ = make_train_step(model, plan, tcfg, mesh)
    jstep = jax.jit(step, donate_argnums=0)
    batch = _batch(cfg, rng, B=4)
    losses = []
    for _ in range(5):
        state, m = jstep(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_consistency(arch, rng):
    """Prefill+decode logits must match the full forward pass — the
    KV/SSM-cache correctness test, per family."""
    entry = get_arch(arch)
    cfg = entry.smoke
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = _batch(cfg, rng, B=B, S=S)
    full = model.apply(params, {k: v for k, v in batch.items() if k != "labels"},
                       remat="none")
    full_logits = np.asarray(full[0].astype(jnp.float32))

    cache = init_params(model.cache_specs(B, S), jax.random.PRNGKey(1))
    if cfg.family == "audio":
        sd = full_logits.shape[1]
        pre = {"frames": batch["frames"], "tokens": batch["tokens"][:, : sd - 1]}
        logits_p, cache = model.prefill(params, pre, cache)
        step_tok = batch["tokens"][:, sd - 1:sd]
        logits_d, _ = model.decode_step(params, cache, step_tok)
        np.testing.assert_allclose(np.asarray(logits_d[:, 0].astype(jnp.float32)),
                                   full_logits[:, -1], rtol=0.1, atol=0.15)
        return
    if cfg.family == "vlm":
        pre = {"embeds": batch["embeds"][:, : S - 1],
               "positions3": batch["positions3"][:, :, : S - 1]}
        logits_p, cache = model.prefill(params, pre, cache)
        logits_d, _ = model.decode_step(
            params, cache, {"embeds": batch["embeds"][:, S - 1:]})
    else:
        pre = {"tokens": batch["tokens"][:, : S - 1]}
        logits_p, cache = model.prefill(params, pre, cache)
        logits_d, _ = model.decode_step(params, cache,
                                        batch["tokens"][:, S - 1:])
    got_p = np.asarray(logits_p.astype(jnp.float32))
    got_d = np.asarray(logits_d[:, 0].astype(jnp.float32))
    if cfg.family == "moe":
        # capacity-based dropping is token-set dependent: prefill (S-1
        # tokens) may drop different tokens than the full pass → a few
        # logits legitimately differ. Require 99% agreement + small mean.
        diff_p = np.abs(got_p - full_logits[:, : S - 1])
        diff_d = np.abs(got_d - full_logits[:, -1])
        assert (diff_p < 0.15).mean() > 0.99 and diff_p.mean() < 0.05
        assert (diff_d < 0.15).mean() > 0.99 and diff_d.mean() < 0.05
        return
    # prefill logits match
    np.testing.assert_allclose(got_p, full_logits[:, : S - 1], rtol=0.1, atol=0.15)
    # decode step matches the last position
    np.testing.assert_allclose(got_d, full_logits[:, -1], rtol=0.1, atol=0.15)


def test_flash_attention_matches_naive(rng):
    from repro.models.common import _sdpa, _sdpa_flash
    B, H, KV, D = 2, 8, 2, 16
    for Sq, Sk, causal in [(64, 64, True), (1, 128, True), (32, 96, False)]:
        q = jnp.asarray(rng.normal(size=(B, Sq, H, D)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(B, Sk, KV, D)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(B, Sk, KV, D)).astype(np.float32))
        a = _sdpa(q, k, v, causal=causal, q_offset=Sk - Sq)
        b = _sdpa_flash(q, k, v, causal=causal, q_offset=Sk - Sq, kv_chunk=32)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_mla_absorbed_matches_expanded(rng):
    """deepseek MLA: absorbed-matmul decode == expanded K/V attention."""
    from repro.models.moe import mla_attention
    entry = get_arch("deepseek-v3-671b")
    cfg = entry.smoke
    from repro.models.moe import mla_specs
    from repro.sharding.spec import init_params as ip
    p = ip(mla_specs(cfg, jnp.float32), jax.random.PRNGKey(0))
    B, S = 2, 12
    x = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)).astype(np.float32))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    out_e, _ = mla_attention(cfg, p, x, pos, absorbed=False,
                             compute_dtype=jnp.float32)
    out_a, _ = mla_attention(cfg, p, x, pos, absorbed=True,
                             compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out_e), np.asarray(out_a),
                               rtol=1e-3, atol=1e-4)


def test_ssd_scan_matches_sequential(rng):
    """Mamba2 chunked SSD == naive per-step recurrence."""
    from repro.models.ssm import ssd_scan
    B, S, H, P, N = 2, 32, 3, 8, 4
    x = jnp.asarray(rng.normal(size=(B, S, H, P)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.1, 0.9, size=(B, S, H)).astype(np.float32))
    A_log = jnp.asarray(rng.normal(size=(H,)).astype(np.float32) * 0.1)
    Bm = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32))
    Cm = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32))
    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    y, hf = ssd_scan(x, dt, A_log, Bm, Cm, h0, chunk=8)

    # naive recurrence
    a = np.asarray(-np.exp(np.asarray(A_log))[None, None] * np.asarray(dt))
    h = np.zeros((B, H, P, N), np.float32)
    ys = np.zeros((B, S, H, P), np.float32)
    xn, bn, cn = map(np.asarray, (x, Bm, Cm))
    dtn = np.asarray(dt)
    for t in range(S):
        h = h * np.exp(a[:, t])[:, :, None, None] + np.einsum(
            "bhp,bn,bh->bhpn", xn[:, t], bn[:, t], dtn[:, t])
        ys[:, t] = np.einsum("bn,bhpn->bhp", cn[:, t], h)
    np.testing.assert_allclose(np.asarray(y), ys, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hf), h, rtol=1e-3, atol=1e-4)


def test_kv_quant_decode_close_to_bf16(rng):
    """int8 KV cache (§Perf variant) keeps decode logits close + argmax."""
    import dataclasses
    entry = get_arch("qwen2.5-14b")
    B, S = 2, 16
    toks = jnp.asarray(rng.integers(0, 256, (B, S)), jnp.int32)
    outs = {}
    for quant in (False, True):
        cfg = dataclasses.replace(entry.smoke, kv_quant=quant)
        model = build_model(cfg)
        params = init_params(model.param_specs(), jax.random.PRNGKey(0))
        cache = init_params(model.cache_specs(B, S), jax.random.PRNGKey(1))
        _, cache = model.prefill(params, {"tokens": toks[:, : S - 1]}, cache)
        ld, _ = model.decode_step(params, cache, toks[:, S - 1:])
        outs[quant] = np.asarray(ld.astype(jnp.float32))
    rel = np.abs(outs[False] - outs[True]).max() / np.abs(outs[False]).max()
    assert rel < 0.15, rel
    assert (outs[False].argmax(-1) == outs[True].argmax(-1)).all()
