"""Index subsystem tests: batched-racing parity with per-query knn(),
mutation (insert/delete/compact) correctness, checkpoint round-trip, and
warm-start plumbing."""
import os

import jax
import numpy as np
import pytest

from repro.configs.base import BMOConfig
from repro.core import bmo_nn, oracle
from repro.core.datasets import SparseDataset
from repro.data.synthetic import clustered_sparse, make_knn_benchmark_data
from repro.index import (IndexStore, build_index, compact, delete, index_knn,
                         insert, load_index, maybe_compact, save_index)
from repro.index.batched_race import fused_race_topk


def _sets(idx):
    return [set(np.asarray(idx[i]).tolist()) for i in range(idx.shape[0])]


# ---------------------------------------------------------------------------
# batched racing parity: index.batched_race == per-query knn() top-k
# ---------------------------------------------------------------------------


def test_batched_parity_dense():
    corpus, queries = make_knn_benchmark_data("dense", 400, 1024, 6, seed=1)
    cfg = BMOConfig(k=3, delta=0.01, block=64, batch_arms=16,
                    pulls_per_round=2, metric="l2")
    per = bmo_nn.knn(corpus, queries, cfg, jax.random.PRNGKey(0))
    store = build_index(corpus, cfg, jax.random.PRNGKey(0))
    res = index_knn(store, queries, jax.random.PRNGKey(1))
    assert _sets(res.indices) == _sets(per.indices)
    # both exact → values agree too (sorted ascending per row)
    np.testing.assert_allclose(np.asarray(res.values), np.asarray(per.values),
                               rtol=1e-4, atol=1e-5)


def test_batched_parity_rotated():
    corpus, queries = make_knn_benchmark_data("dense", 300, 512, 4, seed=2)
    cfg = BMOConfig(k=3, delta=0.01, block=64, batch_arms=16, metric="l2",
                    rotate=True)
    per = bmo_nn.knn(corpus, queries, cfg, jax.random.PRNGKey(0))
    store = build_index(corpus, cfg, jax.random.PRNGKey(0))
    res = index_knn(store, queries, jax.random.PRNGKey(1))
    assert _sets(res.indices) == _sets(per.indices)


def test_batched_parity_sparse():
    corpus = clustered_sparse(200, 2048, seed=4)
    ds = SparseDataset.build(corpus)
    qi, qv, qn = ds.indices[:4], ds.values[:4], ds.nnz[:4]
    cfg = BMOConfig(k=3, delta=0.01, block=1, batch_arms=16,
                    pulls_per_round=8, init_pulls=16, metric="l1", sparse=True)
    per = bmo_nn.knn(ds, (qi, qv, qn), cfg, jax.random.PRNGKey(3))
    store = build_index(corpus, cfg, jax.random.PRNGKey(0))
    res = index_knn(store, (qi, qv, qn), jax.random.PRNGKey(5))
    assert _sets(res.indices) == _sets(per.indices)


def test_k_exceeding_live_slots_raises():
    corpus = np.random.default_rng(0).normal(size=(8, 256)).astype(np.float32)
    cfg = BMOConfig(k=5, delta=0.05, block=32, batch_arms=4, metric="l2")
    store = build_index(corpus, cfg, jax.random.PRNGKey(0))
    store = delete(store, [0, 1, 2, 3, 4, 5])
    with pytest.raises(ValueError, match="live slots"):
        index_knn(store, corpus[:1], jax.random.PRNGKey(1))


def test_batched_respects_k_override_and_cold_start():
    corpus, queries = make_knn_benchmark_data("dense", 128, 256, 2, seed=7)
    cfg = BMOConfig(k=5, delta=0.05, block=32, batch_arms=16, metric="l2")
    store = build_index(corpus, cfg, jax.random.PRNGKey(0))
    ex = oracle.exact_knn(corpus, queries, 2, "l2")
    res = index_knn(store, queries, jax.random.PRNGKey(1), k=2,
                    warm_start=False)
    assert res.indices.shape == (2, 2)
    assert _sets(res.indices) == _sets(ex.indices)


# ---------------------------------------------------------------------------
# epoch-fused driver (DESIGN.md §4): parity + frontier-compaction invariants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rotate", [False, True])
def test_fused_vs_rounds_driver_parity(rotate):
    """The epoch-fused survivor-compacted driver and the PR-1 per-round
    driver certify the same top-k (both exact w.h.p.) on dense/rotated."""
    corpus, queries = make_knn_benchmark_data("dense", 500, 1024, 5, seed=21)
    cfg = BMOConfig(k=3, delta=0.01, block=64, batch_arms=16,
                    pulls_per_round=2, metric="l2", rotate=rotate)
    store = build_index(corpus, cfg, jax.random.PRNGKey(0))
    fused = index_knn(store, queries, jax.random.PRNGKey(1), mode="fused")
    rounds = index_knn(store, queries, jax.random.PRNGKey(1), mode="rounds")
    ex = oracle.exact_knn(corpus, queries, 3, "l2")
    assert _sets(fused.indices) == _sets(rounds.indices) == _sets(ex.indices)
    np.testing.assert_allclose(np.asarray(fused.values),
                               np.asarray(rounds.values), rtol=1e-4, atol=1e-5)


def test_fused_mode_rejected_for_sparse():
    corpus = clustered_sparse(50, 256, seed=9)
    cfg = BMOConfig(k=2, delta=0.05, block=1, batch_arms=8, pulls_per_round=8,
                    init_pulls=16, metric="l1", sparse=True)
    store = build_index(corpus, cfg, jax.random.PRNGKey(0))
    ds = SparseDataset.build(corpus[:1])
    with pytest.raises(ValueError, match="sparse"):
        index_knn(store, (ds.indices, ds.values, ds.nnz),
                  jax.random.PRNGKey(1), mode="fused")


def test_frontier_compaction_invariant():
    """Compaction only drops rejected/padding entries: the race must make
    *identical* decisions with and without it — same accepted ids, same
    surviving candidate ids, same top-k, same rounds and coordinate-ops."""
    corpus, queries = make_knn_benchmark_data("dense", 300, 1024, 4, seed=33)
    cfg = BMOConfig(k=3, delta=0.01, block=64, batch_arms=16,
                    pulls_per_round=2, metric="l2")
    store = build_index(corpus, cfg, jax.random.PRNGKey(0))
    qs = store.prepare_queries(queries)
    kw = dict(cfg=cfg, block=store.block, d=store.d, impl="auto",
              eliminate=True, prior_weight=store.prior_weight,
              _return_state=True)
    res_c, st_c = fused_race_topk(store.x, qs, store.alive, store.prior_var,
                                  jax.random.PRNGKey(5), compaction=True, **kw)
    res_u, st_u = fused_race_topk(store.x, qs, store.alive, store.prior_var,
                                  jax.random.PRNGKey(5), compaction=False, **kw)
    assert st_c.width < st_u.width  # compaction actually shrank the buffers

    def id_sets(st, mask):
        m, ids = np.asarray(mask), np.asarray(st.ids)
        return [set(ids[q][m[q]].tolist()) for q in range(ids.shape[0])]

    acc_c = id_sets(st_c, st_c.accepted & st_c.valid)
    acc_u = id_sets(st_u, st_u.accepted & st_u.valid)
    assert acc_c == acc_u
    surv_c = id_sets(st_c, st_c.valid & ~st_c.rejected & ~st_c.accepted)
    surv_u = id_sets(st_u, st_u.valid & ~st_u.rejected & ~st_u.accepted)
    assert surv_c == surv_u
    np.testing.assert_array_equal(np.asarray(res_c.indices),
                                  np.asarray(res_u.indices))
    np.testing.assert_allclose(np.asarray(res_c.values),
                               np.asarray(res_u.values), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(res_c.rounds),
                                  np.asarray(res_u.rounds))
    np.testing.assert_array_equal(np.asarray(res_c.n_exact),
                                  np.asarray(res_u.n_exact))
    np.testing.assert_allclose(np.asarray(res_c.coord_ops),
                               np.asarray(res_u.coord_ops), rtol=1e-6)


def test_fused_driver_respects_tombstones_and_k_override():
    corpus, queries = make_knn_benchmark_data("dense", 200, 512, 3, seed=12)
    cfg = BMOConfig(k=4, delta=0.01, block=64, batch_arms=16, metric="l2")
    store = build_index(corpus, cfg, jax.random.PRNGKey(0))
    ex = oracle.exact_knn(corpus, queries, 4, "l2")
    kill = np.asarray(ex.indices[0])[:2].tolist()
    store = delete(store, kill)
    res = index_knn(store, queries, jax.random.PRNGKey(2), k=2, mode="fused")
    assert res.indices.shape == (3, 2)
    for row in _sets(res.indices):
        assert not (row & set(kill))


# ---------------------------------------------------------------------------
# mutation: insert / delete / compact
# ---------------------------------------------------------------------------


def _fresh_equals(store, corpus_rows, queries, cfg, slot_of_row):
    """Post-mutation top-k == fresh build on the mutated corpus (slot ids
    mapped through ``slot_of_row``)."""
    fresh = build_index(np.asarray(corpus_rows), cfg, jax.random.PRNGKey(0))
    want = index_knn(fresh, queries, jax.random.PRNGKey(9))
    got = index_knn(store, queries, jax.random.PRNGKey(9))
    want_slots = [set(int(slot_of_row[j]) for j in row)
                  for row in np.asarray(want.indices)]
    got_slots = _sets(got.indices)
    assert got_slots == want_slots


def test_mutation_round_trip_dense():
    corpus, queries = make_knn_benchmark_data("dense", 200, 512, 3, seed=11)
    cfg = BMOConfig(k=3, delta=0.01, block=64, batch_arms=16, metric="l2")
    store = build_index(corpus, cfg, jax.random.PRNGKey(0))
    ex = oracle.exact_knn(corpus, queries, 3, "l2")

    # delete the two best arms of query 0: they must disappear from results
    kill = np.asarray(ex.indices[0])[:2].tolist()
    store = delete(store, kill)
    res = index_knn(store, queries, jax.random.PRNGKey(1))
    for row in _sets(res.indices):
        assert not (row & set(kill))
    # equivalent fresh build on the corpus without the deleted rows
    mask = np.ones(len(corpus), bool)
    mask[kill] = False
    slot_of_row = np.nonzero(mask)[0]
    _fresh_equals(store, corpus[mask], queries, cfg, slot_of_row)

    # insert near-duplicates of the queries: they must become the top-1,
    # landing in the freed slots
    store, slots = insert(store, queries + 1e-3)
    assert set(slots.tolist()) <= set(kill) | set(
        range(200, store.capacity))
    res = index_knn(store, queries, jax.random.PRNGKey(2))
    for i in range(len(queries)):
        assert int(np.asarray(res.indices[i])[0]) == int(slots[i])

    # compact: same results through the old→new slot mapping
    before = index_knn(store, queries, jax.random.PRNGKey(3))
    store2, old_ids = compact(store)
    assert store2.n_live == store.n_live
    after = index_knn(store2, queries, jax.random.PRNGKey(3))
    remapped = [set(int(old_ids[j]) for j in row)
                for row in np.asarray(after.indices)]
    assert remapped == _sets(before.indices)


def test_mutation_growth_and_widen_sparse():
    corpus = clustered_sparse(60, 512, seed=6)
    cfg = BMOConfig(k=2, delta=0.01, block=1, batch_arms=16,
                    pulls_per_round=8, init_pulls=16, metric="l1", sparse=True)
    store = build_index(corpus, cfg, jax.random.PRNGKey(0), capacity=64)
    m0 = store.m
    # a denser row than any existing one forces a column widen; 5 rows force
    # a capacity growth (64 - 60 = 4 free)
    rng = np.random.default_rng(0)
    dense_rows = np.where(rng.random((5, 512)) < 0.5,
                          rng.exponential(1.0, (5, 512)), 0).astype(np.float32)
    store, slots = insert(store, dense_rows)
    assert store.capacity > 64 and store.m > m0 and len(slots) == 5
    ds_q = SparseDataset.build(dense_rows[:1])
    res = index_knn(store, (ds_q.indices, ds_q.values, ds_q.nnz),
                    jax.random.PRNGKey(1))
    assert int(np.asarray(res.indices[0])[0]) == int(slots[0])


def test_maybe_compact_threshold_policy():
    """Auto-compaction (ROADMAP): no-op below the tombstone threshold, a
    real capacity-shrinking compact above it, old→new map returned."""
    corpus, queries = make_knn_benchmark_data("dense", 120, 256, 2, seed=17)
    cfg = BMOConfig(k=2, delta=0.05, block=32, batch_arms=16, metric="l2")
    store = build_index(corpus, cfg, jax.random.PRNGKey(0))   # cap 128
    same, old_ids = maybe_compact(store, threshold=0.5)
    assert old_ids is None and same is store                  # 8/128 dead

    store = delete(store, list(range(60, 120)))               # 68/128 dead
    compacted, old_ids = maybe_compact(store, threshold=0.5)
    assert old_ids is not None
    assert compacted.capacity == 64 and compacted.n_live == 60
    # results identical through the slot map
    want = index_knn(store, queries, jax.random.PRNGKey(3))
    got = index_knn(compacted, queries, jax.random.PRNGKey(3))
    remapped = [set(int(old_ids[j]) for j in row)
                for row in np.asarray(got.indices)]
    assert remapped == _sets(want.indices)


# ---------------------------------------------------------------------------
# persistence via checkpoint/manager.py
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind_cfg", [
    ("dense", dict(metric="l2", block=64)),
    ("rotated", dict(metric="l2", block=64, rotate=True)),
    ("sparse", dict(metric="l1", block=1, pulls_per_round=8, init_pulls=16,
                    sparse=True)),
])
def test_save_load_round_trip(tmp_path, kind_cfg):
    kind, kw = kind_cfg
    cfg = BMOConfig(k=3, delta=0.01, batch_arms=16, **kw)
    if kind == "sparse":
        corpus = clustered_sparse(100, 512, seed=3)
        ds = SparseDataset.build(corpus)
        queries = (ds.indices[:2], ds.values[:2], ds.nnz[:2])
    else:
        corpus, queries = make_knn_benchmark_data("dense", 100, 256, 2, seed=3)
    store = build_index(corpus, cfg, jax.random.PRNGKey(0))
    path = os.path.join(tmp_path, "idx")
    save_index(store, path)
    store2 = load_index(path)
    assert isinstance(store2, IndexStore) and store2.kind == store.kind
    r1 = index_knn(store, queries, jax.random.PRNGKey(1))
    r2 = index_knn(store2, queries, jax.random.PRNGKey(1))
    np.testing.assert_array_equal(np.asarray(r1.indices), np.asarray(r2.indices))
    np.testing.assert_allclose(np.asarray(r1.values), np.asarray(r2.values))


# ---------------------------------------------------------------------------
# degenerate sparse arms (satellite regression: empty-support path)
# ---------------------------------------------------------------------------


def test_sparse_empty_support_arm():
    """All-zero corpus rows (nnz == 0) must race cleanly: θ̂ pulls are 0 when
    the support union is empty, finite otherwise, and the racer returns the
    right neighbours."""
    d = 64
    corpus = np.zeros((6, d), np.float32)
    corpus[0, [1, 5]] = [1.0, 2.0]
    corpus[1, [2]] = [0.5]
    # rows 2..5 all-zero
    ds = SparseDataset.build(corpus)
    assert int(ds.nnz[2]) == 0

    # pulls against an empty query AND an empty arm are exactly 0
    key = jax.random.PRNGKey(0)
    empty_q = SparseDataset.build(np.zeros((1, d), np.float32))
    vals = jax.vmap(lambda kk: bmo_nn.sparse_pull_one(
        ds, empty_q.indices[0], empty_q.values[0], empty_q.nnz[0], 2, kk))(
        jax.random.split(key, 32))
    np.testing.assert_array_equal(np.asarray(vals), 0.0)

    # a zero query's nearest neighbours are the zero rows (θ = 0)
    cfg = BMOConfig(k=3, delta=0.05, block=1, batch_arms=4, pulls_per_round=4,
                    init_pulls=8, metric="l1", sparse=True)
    res = bmo_nn.knn(ds, (empty_q.indices, empty_q.values, empty_q.nnz),
                     cfg, jax.random.PRNGKey(1))
    assert set(np.asarray(res.indices[0]).tolist()) <= {2, 3, 4, 5}
    np.testing.assert_allclose(np.asarray(res.values[0]), 0.0, atol=1e-6)

    # and the batched index path handles tombstoned + empty rows together
    store = build_index(corpus, cfg, jax.random.PRNGKey(0))
    store = delete(store, [2])
    bres = index_knn(store, (empty_q.indices, empty_q.values, empty_q.nnz),
                     jax.random.PRNGKey(2))
    got = set(np.asarray(bres.indices[0]).tolist())
    assert got <= {3, 4, 5} and 2 not in got
