"""Multi-device tests (subprocess with xla_force_host_platform_device_count):
distributed BMO-NN, sharded training parity, elastic restore, gradient
compression, MoE expert parallelism."""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(prog: str, devices: int = 8, timeout: int = 560):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    # `import repro` first: installs the jax-version compat shims
    # (repro._compat) before the snippet touches jax.make_mesh/AxisType
    out = subprocess.run([sys.executable, "-c",
                          "import repro\n" + textwrap.dedent(prog)],
                         capture_output=True, text=True, env=env,
                         cwd=ROOT, timeout=timeout)
    assert out.returncode == 0 and "OK" in out.stdout, \
        f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-3000:]}"


def test_distributed_knn_exact():
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        from repro.configs.base import BMOConfig
        from repro.core.distributed import distributed_knn
        from repro.core import oracle
        from repro.data.synthetic import make_knn_benchmark_data
        X, qs = make_knn_benchmark_data("dense", 256, 1024, 4, seed=0)
        ex = oracle.exact_knn(X, qs, 3, "l2")
        cfg = BMOConfig(k=3, delta=0.01, block=64, batch_arms=16,
                        pulls_per_round=2, init_pulls=4, metric="l2")
        res = distributed_knn(jnp.asarray(X), jnp.asarray(qs), cfg, mesh,
                              jax.random.PRNGKey(0), impl="ref")
        acc = np.mean([set(np.asarray(res.indices[i])) ==
                       set(np.asarray(ex.indices[i])) for i in range(4)])
        assert acc == 1.0, acc
        print("OK")
    """)


def test_sharded_train_matches_single_device():
    _run("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import TrainConfig, get_arch
        from repro.models import build_model
        from repro.train.steps import (batch_pspecs, init_train_state,
                                       make_train_step, state_pspecs, to_named)
        entry = get_arch("qwen2.5-14b")
        model = build_model(entry.smoke)
        tcfg = TrainConfig(total_steps=4, lr=1e-3)
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(0, 256, (8, 32)), jnp.int32),
                 "labels": jnp.asarray(rng.integers(0, 256, (8, 32)), jnp.int32)}
        outs = []
        for shape in [(1, 1), (4, 2)]:
            mesh = jax.make_mesh(shape, ("data", "model"),
                                 axis_types=(jax.sharding.AxisType.Auto,)*2)
            plan = dataclasses.replace(entry.plan, fsdp=True, tp=True, sp=True,
                                       grad_accum=2, param_dtype="float32")
            state = init_train_state(model, plan, tcfg, jax.random.PRNGKey(0))
            step, rules = make_train_step(model, plan, tcfg, mesh)
            sh = to_named(state_pspecs(model, plan, rules), mesh)
            state = jax.device_put(state, sh)
            new_state, m = jax.jit(step)(state, batch)
            outs.append((float(m["loss"]), new_state["params"]))
        assert abs(outs[0][0] - outs[1][0]) < 1e-3, (outs[0][0], outs[1][0])
        for a, b in zip(jax.tree_util.tree_leaves(outs[0][1]),
                        jax.tree_util.tree_leaves(outs[1][1])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-2, atol=2e-4)
        print("OK")
    """)


def test_moe_expert_parallel_matches_local():
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.configs import get_arch
        from repro.models.moe import moe_specs, moe_apply
        from repro.sharding.spec import init_params
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        cfg = get_arch("dbrx-132b").smoke
        p = init_params(moe_specs(cfg, jnp.float32), jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model),
                              jnp.float32)
        out_local, aux_local = moe_apply(cfg, p, x, ep=False,
                                         compute_dtype=jnp.float32)
        out_ep, aux_ep = moe_apply(cfg, p, x, mesh=mesh, ep=True,
                                   dp_spec="data", compute_dtype=jnp.float32)
        # same routing; capacity differs (per-shard) → compare where both kept
        diff = np.abs(np.asarray(out_local) - np.asarray(out_ep))
        frac_close = float((diff < 1e-3).mean())
        assert frac_close > 0.95, frac_close
        print("OK")
    """)


def test_elastic_restore_8_to_4_devices(tmp_path):
    prog_a = f"""
        import dataclasses
        import jax, numpy as np
        from repro.checkpoint import CheckpointManager
        from repro.configs import TrainConfig, get_arch
        from repro.data.loader import ShardedLoader
        from repro.models import build_model
        from repro.runtime.elastic import make_elastic_mesh, reshard_state
        from repro.train.steps import init_train_state, make_train_step
        entry = get_arch("xlstm-350m")
        model = build_model(entry.smoke)
        plan = dataclasses.replace(entry.plan, grad_accum=1, param_dtype="float32")
        tcfg = TrainConfig(total_steps=12, lr=1e-3)
        mesh = make_elastic_mesh(prefer_model=2)
        assert mesh.devices.size == {{DEV}}, mesh.devices.shape
        state = init_train_state(model, plan, tcfg, jax.random.PRNGKey(0))
        state, rules = reshard_state(model, plan, mesh, state)
        step, _ = make_train_step(model, plan, tcfg, mesh, rules=rules)
        jstep = jax.jit(step, donate_argnums=0)
        loader = ShardedLoader(model.cfg.vocab_size, 8, 32, seed=3)
        ck = CheckpointManager(r"{str(tmp_path)}", keep=2, async_save=False)
        start = 0
        st, meta = ck.restore_latest(jax.eval_shape(
            lambda: init_train_state(model, plan, tcfg, jax.random.PRNGKey(0))))
        if st is not None:
            state, _ = reshard_state(model, plan, mesh, st)
            start = int(meta["step"]) + 1
        for s in range(start, {{STOP}}):
            state, m = jstep(state, loader.get(s))
        ck.save({{STOP}} - 1, state)
        ck.wait()
        print("OK", float(m["loss"]))
    """
    _run(prog_a.replace("{DEV}", "8").replace("{STOP}", "6"), devices=8)
    _run(prog_a.replace("{DEV}", "4").replace("{STOP}", "12"), devices=4)


def test_compressed_psum_convergence():
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.optim.compress import compressed_psum, init_error
        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        g_global = jax.random.normal(jax.random.PRNGKey(0), (8, 256))

        def fn(g, e):
            mean, new_e = compressed_psum({"g": g[0]}, "data", {"g": e[0]})
            return mean["g"], new_e["g"][None]

        e0 = jnp.zeros((8, 256))
        f = jax.shard_map(fn, mesh=mesh,
                          in_specs=(P("data"), P("data")),
                          out_specs=(P(), P("data")), check_vma=False)
        got, e1 = f(g_global[:, None, :].reshape(8, 1, 256), e0[:, None, :].reshape(8,1,256))
        want = g_global.mean(0)
        err1 = float(jnp.abs(got - want).max())
        # error feedback: average of dequantized + carried error == exact over time
        got2, _ = f(g_global[:, None, :].reshape(8,1,256), e1.reshape(8,1,256))
        assert err1 < 0.05, err1
        print("OK", err1)
    """)


def test_dryrun_driver_smoke_small_mesh():
    """Exercise the dry-run code path itself on an 8-device host mesh by
    monkeypatching make_production_mesh (full 512-dev cells run in the
    dedicated sweep, not in unit tests)."""
    _run("""
        import jax
        import repro.launch.mesh as M
        def small(multi_pod=False):
            if multi_pod:
                return jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                                     axis_types=(jax.sharding.AxisType.Auto,)*3)
            return jax.make_mesh((4, 2), ("data", "model"),
                                 axis_types=(jax.sharding.AxisType.Auto,)*2)
        M.make_production_mesh = small
        import repro.launch.dryrun as D
        D.make_production_mesh = small
        import dataclasses
        import repro.configs.registry as R
        entry = R.get_arch("qwen2.5-14b")
        # shrink the arch so the 8-dev compile is fast
        object.__setattr__ if False else None
        import repro.configs.qwen2_5_14b as Q
        Q.CONFIG = entry.smoke
        rec = D.run_cell("qwen2.5-14b", "train_4k", "single",
                         overrides={"plan.grad_accum": 2})
        assert rec["status"] == "ok", rec
        rec2 = D.run_cell("qwen2.5-14b", "decode_32k", "multi")
        assert rec2["status"] == "ok", rec2
        print("OK", rec["bottleneck"], rec2["bottleneck"])
    """, devices=8, timeout=560)
