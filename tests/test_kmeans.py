"""BMO k-means (paper §V-A): bandit assignment step vs exact Lloyd."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import BMOConfig
from repro.core import kmeans
from repro.data.synthetic import clustered_dense


def test_assignment_matches_exact():
    pts = clustered_dense(300, 512, n_clusters=8, noise=0.05, seed=0)
    cents = pts[:10]
    cfg = BMOConfig(k=1, delta=0.01, block=64, batch_arms=8,
                    pulls_per_round=2, metric="l2")
    a_bmo, ops = kmeans.assign_bmo(jnp.asarray(pts), jnp.asarray(cents), cfg,
                                   jax.random.PRNGKey(0))
    a_ex, _ = kmeans.assign_exact(jnp.asarray(pts), jnp.asarray(cents))
    acc = float(np.mean(np.asarray(a_bmo) == np.asarray(a_ex)))
    assert acc >= 0.99, acc


def test_kmeans_objective_decreases():
    pts = clustered_dense(200, 256, n_clusters=4, noise=0.05, seed=1)
    cfg = BMOConfig(k=1, delta=0.05, block=32, batch_arms=8, metric="l2")

    def objective(res):
        d = pts - np.asarray(res.centroids)[np.asarray(res.assignment)]
        return float((d ** 2).sum())

    r1 = kmeans.kmeans(pts, 4, 1, cfg, jax.random.PRNGKey(2))
    r3 = kmeans.kmeans(pts, 4, 3, cfg, jax.random.PRNGKey(2))
    assert objective(r3) <= objective(r1) * 1.01


def test_kmeans_counts_ops():
    pts = clustered_dense(128, 256, n_clusters=4, seed=2)
    cfg = BMOConfig(k=1, delta=0.05, block=32, batch_arms=8, metric="l2")
    res = kmeans.kmeans(pts, 4, 2, cfg, jax.random.PRNGKey(3))
    assert float(res.coord_ops) > 0
    assert float(res.exact_ops) == 2 * 128 * 4 * 256


def test_lloyd_update_means():
    pts = jnp.asarray([[0.0, 0.0], [2.0, 2.0], [10.0, 10.0]])
    assign = jnp.asarray([0, 0, 1])
    c = kmeans.lloyd_update(pts, assign, 2)
    np.testing.assert_allclose(np.asarray(c), [[1.0, 1.0], [10.0, 10.0]])
