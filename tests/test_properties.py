"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.kernels import ref


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 6), st.integers(1, 6))
def test_fwht_involution(log_d, n):
    """H is orthonormal and symmetric → FWHT is its own inverse."""
    d = 1 << log_d
    rng = np.random.default_rng(log_d * 7 + n)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    y = ref.fwht_ref(ref.fwht_ref(x))
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 5), st.integers(1, 64))
def test_fwht_preserves_norm(n, seed):
    d = 256
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    y = ref.fwht_ref(x)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=1),
                               np.linalg.norm(np.asarray(x), axis=1),
                               rtol=1e-5)


@settings(max_examples=25, deadline=None)
@given(st.integers(4, 64), st.integers(2, 16), st.integers(1, 4),
       st.integers(2, 10))
def test_moe_dispatch_no_collisions(T, E, k, cap):
    """Every kept token-slot maps to a unique buffer slot in its expert's
    capacity range; dropped slots map out of bounds."""
    from repro.models.moe import _dispatch_indices
    k = min(k, E)
    rng = np.random.default_rng(T * 100 + E)
    expert_ids = jnp.asarray(rng.integers(0, E, T * k), jnp.int32)
    dest, order, keep = map(np.asarray, _dispatch_indices(
        jnp.asarray(expert_ids), E, cap))
    kept = dest[keep]
    assert len(set(kept.tolist())) == len(kept)          # no collisions
    assert (kept < E * cap).all()
    assert (dest[~keep] == E * cap).all()                # dropped → sentinel
    # each kept slot's expert bucket matches its expert id
    sorted_e = np.asarray(expert_ids)[order]
    assert ((kept // cap) == sorted_e[keep]).all()
    # per-expert kept count ≤ cap and = min(count, cap)
    for e in range(E):
        cnt = int((sorted_e == e).sum())
        kept_e = int(((kept // cap) == e).sum())
        assert kept_e == min(cnt, cap)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 100), st.floats(0.01, 100.0))
def test_quantize_roundtrip_error(seed, scale):
    from repro.optim.compress import _quantize
    rng = np.random.default_rng(seed)
    g = jnp.asarray((rng.normal(size=64) * scale).astype(np.float32))
    q, s = _quantize(g)
    err = np.abs(np.asarray(g) - np.asarray(q, np.float32) * float(s))
    assert err.max() <= float(s) / 2 + 1e-6
    assert np.abs(np.asarray(q)).max() <= 127


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 32), st.integers(2, 8))
def test_race_topk_identifies_separated_arms(n, k):
    """With well-separated deterministic arms, racing must return the true
    top-k (pull noise ~ tiny)."""
    from repro.configs.base import BMOConfig
    from repro.core.ucb import race_topk
    k = min(k, n - 1)
    rng = np.random.default_rng(n * 17 + k)
    means = np.sort(rng.uniform(0, 1, n)).astype(np.float32)
    means = means + np.arange(n, dtype=np.float32)  # gaps ≥ ~1

    def pull(arm_idx, key):
        noise = jax.random.normal(key, (arm_idx.shape[0], 2)) * 0.01
        return jnp.asarray(means)[arm_idx][:, None] + noise

    def exact(arm_idx):
        return jnp.asarray(means)[arm_idx]

    cfg = BMOConfig(k=k, delta=0.05, batch_arms=min(8, n), pulls_per_round=2)
    res = race_topk(pull, exact, n=n, max_pulls=64, pull_cost=1.0,
                    exact_cost=64.0, cfg=cfg, rng=jax.random.PRNGKey(0))
    assert set(np.asarray(res.topk).tolist()) == set(range(k))


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 6), st.integers(8, 40))
def test_sparse_dataset_roundtrip(n, d):
    from repro.core.datasets import SparseDataset
    rng = np.random.default_rng(n * d)
    mask = rng.random((n, d)) < 0.3
    x = np.where(mask, rng.normal(size=(n, d)), 0).astype(np.float32)
    ds = SparseDataset.build(x)
    dense = np.zeros((n, d), np.float32)
    idx, vals = np.asarray(ds.indices), np.asarray(ds.values)
    for i in range(n):
        real = idx[i] < d
        dense[i, idx[i][real]] = vals[i][real]
    np.testing.assert_array_equal(dense, x)
    # indices sorted with sentinel padding
    assert (np.diff(idx, axis=1) >= 0).all()
