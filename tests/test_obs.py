"""PR-6 observability coverage (DESIGN.md §8): metrics-registry and
ring-buffer unit semantics, Prometheus/JSON exporter formats, per-ticket
trace-span completeness across the race boxes (dense / rotated / sparse at
S=1, plus S=4 on a forced 4-device mesh as a subprocess), the shed span,
the no-epoch-mixing guarantee across the mutation fence (both modes), the
empty-window latency-percentile regression, structured trace-id logging,
the Chrome-trace writer, the committed sample trace render, and the
kernel launch/coord-op accounting counters.

Every plane/race test uses a private ``ObsContext`` injected via the
``obs=`` kwarg so tests never race each other through the process-default
context.
"""
import collections
import json
import logging
import math
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.api import Index
from repro.configs.base import BMOConfig
from repro.data.synthetic import clustered_sparse, make_knn_benchmark_data
from repro.obs import (ObsContext, events_doc, json_snapshot,
                       prometheus_text)
from repro.obs.registry import EventLog, Histogram, MetricsRegistry
from repro.obs.trace import NULL_SPAN, Tracer, new_trace_id
from repro.serve.plane import PlaneConfig, RequestPlane

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(prog: str, devices: int = 4, timeout: int = 560):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c",
                          "import repro\n" + textwrap.dedent(prog)],
                         capture_output=True, text=True, env=env,
                         cwd=ROOT, timeout=timeout)
    assert out.returncode == 0 and "OK" in out.stdout, \
        f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-3000:]}"


def _dense_index(n=256, d=512, Q=4, seed=1, **kw):
    corpus, queries = make_knn_benchmark_data("dense", n, d, Q, seed=seed)
    cfg = BMOConfig(k=4, delta=0.01, block=64, batch_arms=16,
                    pulls_per_round=2, metric="l2", **kw)
    return Index.build(corpus, cfg, jax.random.PRNGKey(0)), queries


def _sparse_index():
    corpus = clustered_sparse(200, 2048, seed=4)
    cfg = BMOConfig(k=3, delta=0.01, block=1, batch_arms=16,
                    pulls_per_round=8, init_pulls=16, metric="l1",
                    sparse=True)
    idx = Index.build(corpus, cfg, jax.random.PRNGKey(0))
    from repro.core.datasets import SparseDataset
    ds = SparseDataset.build(corpus)
    return idx, (ds.indices[:4], ds.values[:4], ds.nnz[:4])


def _events(obs, name=None, trace=None):
    evs = obs.events.snapshot()
    if name is not None:
        evs = [e for e in evs if e["name"] == name]
    if trace is not None:
        evs = [e for e in evs if e.get("trace") == trace]
    return evs


# ---------------------------------------------------------------------------
# registry / ring / tracer units
# ---------------------------------------------------------------------------


def test_counter_gauge_histogram_semantics():
    reg = MetricsRegistry()
    c = reg.counter("repro_test_total", "help")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)
    g = reg.gauge("repro_test_depth", "help")
    g.set(7)
    g.dec(3)
    g.inc(1)
    assert g.value == 5
    h = reg.histogram("repro_test_ms", "help")
    for v in (0.3, 3.0, 40.0):
        h.observe(v)
    h.observe(float("nan"))               # skipped, never poisons sum
    snap = h.snapshot()
    assert snap["count"] == 3 and math.isfinite(snap["sum"])
    assert sum(snap["counts"]) == 3       # per-bucket, non-cumulative
    assert len(snap["counts"]) == len(snap["buckets"]) + 1
    # registering again with the same (name, labels) returns the instance
    assert reg.counter("repro_test_total", "help") is c
    with pytest.raises(ValueError):       # same name, different type
        reg.gauge("repro_test_total", "help")


def test_registry_labels_are_distinct_series():
    reg = MetricsRegistry()
    a = reg.counter("repro_x_total", "h", shard="0")
    b = reg.counter("repro_x_total", "h", shard="1")
    a.inc(2)
    b.inc(5)
    assert a is not b and a.value == 2 and b.value == 5
    names = [(m.name, m.labels) for m in reg.collect()]
    assert (("repro_x_total", (("shard", "0"),)) in names
            or ("repro_x_total", {"shard": "0"}) in names
            or any(n == "repro_x_total" for n, _ in names))


def test_histogram_quantiles_and_empty():
    h = Histogram("h", "help", buckets=(1.0, 10.0, 100.0))
    assert h.quantile(0.99) == 0.0        # empty window -> 0.0, never NaN
    for _ in range(90):
        h.observe(0.5)
    for _ in range(10):
        h.observe(50.0)
    p50, p99 = h.quantile(0.5), h.quantile(0.99)
    assert 0.0 <= p50 <= 1.0
    assert 10.0 <= p99 <= 100.0
    assert not math.isnan(p50) and not math.isnan(p99)


def test_event_log_ring_drops_oldest():
    log = EventLog(capacity=4)
    for i in range(7):
        log.append({"name": f"e{i}", "ts": float(i)})
    snap = log.snapshot()
    assert [e["name"] for e in snap] == ["e3", "e4", "e5", "e6"]
    assert log.total == 7 and log.drops == 3 and len(log) == 4
    log.clear()
    assert len(log) == 0 and log.snapshot() == []


def test_tracer_span_and_disabled_null_span():
    log = EventLog(capacity=64)
    tr = Tracer(log, enabled=True)
    with tr.span("work", trace="t-1", k=4):
        pass
    tr.instant("mark", trace="t-1", reason="x")
    evs = log.snapshot()
    assert [e["ph"] for e in evs] == ["X", "i"]
    span_ev = evs[0]
    assert span_ev["name"] == "work" and span_ev["trace"] == "t-1"
    assert span_ev["dur"] >= 0.0 and span_ev["attrs"]["k"] == 4
    off = Tracer(log, enabled=False)
    assert off.start("nope", trace="t-2") is NULL_SPAN
    off.instant("nope", trace="t-2")
    assert len(log.snapshot()) == 2       # disabled tracer logged nothing
    a, b = new_trace_id("s"), new_trace_id("s")
    assert a != b and a.startswith("s-")


def test_obs_context_disabled_keeps_counters():
    obs = ObsContext("t", enabled=False)
    idx, queries = _dense_index()
    s = idx.race(queries, jax.random.PRNGKey(0), obs=obs)
    while s.step():
        pass
    assert len(obs.events) == 0           # no spans recorded
    # ...but the metrics registry stays authoritative
    epochs = [m for m in obs.registry.collect()
              if m.name == "repro_race_epochs_total"]
    assert epochs and sum(m.value for m in epochs) >= 1


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------


def test_prometheus_text_format():
    obs = ObsContext("t")
    obs.registry.counter("repro_a_total", "a counter", plane="p0").inc(3)
    h = obs.registry.histogram("repro_lat_ms", "latencies",
                               buckets=(1.0, 10.0))
    h.observe(0.5)
    h.observe(5.0)
    h.observe(500.0)
    text = prometheus_text(obs.registry)
    lines = text.splitlines()
    assert '# TYPE repro_a_total counter' in lines
    assert 'repro_a_total{plane="p0"} 3' in lines
    # histogram buckets are CUMULATIVE and end with +Inf == _count
    assert 'repro_lat_ms_bucket{le="1"} 1' in lines
    assert 'repro_lat_ms_bucket{le="10"} 2' in lines
    assert 'repro_lat_ms_bucket{le="+Inf"} 3' in lines
    assert 'repro_lat_ms_count 3' in lines
    assert any(ln.startswith("repro_lat_ms_sum ") for ln in lines)


def test_json_snapshot_and_events_doc_roundtrip(tmp_path):
    from repro.api.spec import SCHEMA_VERSION
    from repro.obs import dump_events, dump_metrics
    obs = ObsContext("t")
    obs.registry.counter("repro_a_total", "h").inc()
    obs.tracer.instant("mark", trace="t-1")
    snap = json_snapshot(obs)
    assert snap["schema_version"] == SCHEMA_VERSION
    doc = events_doc(obs)
    assert doc["clock"] == "perf_counter_s" and len(doc["events"]) == 1
    p_json = tmp_path / "m.json"
    p_prom = tmp_path / "m.prom"
    p_ev = tmp_path / "trace.json"
    dump_metrics(str(p_json), obs)
    dump_metrics(str(p_prom), obs)
    dump_events(str(p_ev), obs)
    assert json.loads(p_json.read_text())["schema_version"] == SCHEMA_VERSION
    assert "repro_a_total" in p_prom.read_text()
    assert json.loads(p_ev.read_text())["events"][0]["name"] == "mark"


# ---------------------------------------------------------------------------
# span completeness across the race boxes
# ---------------------------------------------------------------------------


def _assert_ticket_lifecycle(obs, ticket, *, expect_epochs=True):
    """Every admitted ticket yields submit -> queue -> admit -> N epoch
    instants -> exactly one terminal span, all under its trace id."""
    trace = ticket.trace_id
    assert trace, "admitted ticket carries a trace id"
    assert len(_events(obs, "plane.submit", trace)) == 1
    queue = _events(obs, "plane.queue", trace)
    assert queue and all(e["ph"] == "X" for e in queue)
    admits = _events(obs, "plane.admit", trace)
    assert len(admits) >= 1
    sid = admits[-1]["attrs"]["session"]
    term = _events(obs, "plane.terminal", trace)
    assert len(term) == 1
    assert term[0]["attrs"]["reason"] == ticket.result.reason
    assert term[0]["attrs"]["latency_ms"] >= 0.0
    epochs = _events(obs, "ticket.epoch", trace)
    if expect_epochs:
        assert epochs, "racing ticket records per-epoch instants"
        for e in epochs:
            assert e["attrs"]["worst_ci"] >= 0.0
            assert e["attrs"]["epoch"] >= 1
        # the joined session recorded its own race.epoch spans
        race = _events(obs, "race.epoch", sid)
        assert race and all(e["ph"] == "X" for e in race)
        for e in race:
            a = e["attrs"]
            assert a["coord_ops"] >= 0.0 and a["worst_ci"] >= 0.0
    return sid


@pytest.mark.parametrize("kind", ["dense", "rotated", "sparse"])
def test_trace_span_completeness(kind):
    if kind == "sparse":
        idx, queries = _sparse_index()
    else:
        idx, queries = _dense_index(rotate=(kind == "rotated"))
    obs = ObsContext("t")
    plane = RequestPlane(idx, obs=obs)
    t1 = plane.submit(queries, rng=jax.random.PRNGKey(1), cache="bypass")
    t2 = plane.submit(queries, rng=jax.random.PRNGKey(2), cache="bypass")
    plane.drain()
    assert t1.result.reason == "certified"
    sid1 = _assert_ticket_lifecycle(obs, t1)
    sid2 = _assert_ticket_lifecycle(obs, t2)
    assert t1.trace_id != t2.trace_id
    # coalesced into one group -> same session; either way sids join
    assert sid1 and sid2
    # per-epoch telemetry exposes the racing internals
    race = _events(obs, "race.epoch", sid1)
    if kind != "sparse":
        assert all("width" in e["attrs"] and "R" in e["attrs"]
                   for e in race)
    else:
        assert all("R" in e["attrs"] for e in race)


def test_trace_span_completeness_sharded_subprocess():
    """S=4 over a forced 4-device mesh: the race.epoch spans carry the
    per-shard straggler split (coord-ops and rounds per shard)."""
    _run("""
        import jax, numpy as np
        from repro.api import Index
        from repro.configs.base import BMOConfig
        from repro.data.synthetic import (clustered_sparse,
                                          make_knn_benchmark_data)
        from repro.obs import ObsContext
        from repro.serve.plane import RequestPlane

        def events(obs, name, trace=None):
            return [e for e in obs.events.snapshot() if e["name"] == name
                    and (trace is None or e.get("trace") == trace)]

        # dense S=4
        corpus, queries = make_knn_benchmark_data("dense", 256, 512, 4,
                                                  seed=1)
        cfg = BMOConfig(k=4, delta=0.01, block=64, batch_arms=16,
                        pulls_per_round=2, metric="l2")
        idx = Index.build(corpus, cfg, jax.random.PRNGKey(0), shards=4)
        obs = ObsContext("t")
        plane = RequestPlane(idx, obs=obs)
        t = plane.submit(queries, rng=jax.random.PRNGKey(1),
                         cache="bypass")
        plane.drain()
        assert t.result.reason == "certified"
        sid = events(obs, "plane.admit", t.trace_id)[-1]["attrs"]["session"]
        race = events(obs, "race.epoch", sid)
        assert race, "sharded session recorded epoch spans"
        for e in race:
            a = e["attrs"]
            assert a["shards"] == 4
            assert len(a["shard_coord_ops"]) == 4
            assert len(a["shard_rounds"]) == 4
            assert all(v >= 0.0 for v in a["shard_coord_ops"])
        assert events(obs, "ticket.epoch", t.trace_id)
        assert len(events(obs, "plane.terminal", t.trace_id)) == 1

        # sparse S=4
        from repro.core.datasets import SparseDataset
        corpus = clustered_sparse(200, 2048, seed=4)
        scfg = BMOConfig(k=3, delta=0.01, block=1, batch_arms=16,
                         pulls_per_round=8, init_pulls=16, metric="l1",
                         sparse=True)
        sidx = Index.build(corpus, scfg, jax.random.PRNGKey(0), shards=4)
        ds = SparseDataset.build(corpus)
        sq = (ds.indices[:4], ds.values[:4], ds.nnz[:4])
        obs2 = ObsContext("t2")
        plane2 = RequestPlane(sidx, obs=obs2)
        t2 = plane2.submit(sq, rng=jax.random.PRNGKey(1), cache="bypass")
        plane2.drain()
        assert t2.result.reason == "certified"
        sid2 = events(obs2, "plane.admit",
                      t2.trace_id)[-1]["attrs"]["session"]
        for e in events(obs2, "race.epoch", sid2):
            assert len(e["attrs"]["shard_coord_ops"]) == 4
        print("OK")
    """)


def test_shed_ticket_gets_shed_span():
    idx, queries = _dense_index()
    obs = ObsContext("t")
    plane = RequestPlane(idx, PlaneConfig(max_queue=1), obs=obs)
    kept = plane.submit(queries, rng=jax.random.PRNGKey(1), cache="bypass")
    shed = plane.submit(queries, rng=jax.random.PRNGKey(2), cache="bypass")
    assert shed.result is not None and shed.result.reason == "shed"
    evs = _events(obs, "plane.shed", shed.trace_id)
    assert len(evs) == 1 and evs[0]["attrs"]["reason"] == "queue_full"
    assert not _events(obs, "plane.terminal", shed.trace_id)
    plane.drain()
    _assert_ticket_lifecycle(obs, kept)


@pytest.mark.parametrize("mode", ["complete", "readmit"])
def test_trace_epochs_never_mix_store_epochs(mode):
    """The no-mixing guarantee, observable offline: every ticket.epoch
    instant is tagged with the store epoch it raced against, and a single
    ticket's tags never straddle the fence — 'complete' stays entirely on
    the old epoch, 'readmit' switches exactly at the readmit instant."""
    idx, queries = _dense_index()
    obs = ObsContext("t")
    plane = RequestPlane(idx, PlaneConfig(on_mutation=mode), obs=obs)
    epoch0 = idx.epoch
    t = plane.submit(queries, rng=jax.random.PRNGKey(1), cache="bypass")
    plane.step()                          # in flight against epoch0
    idx.insert(np.asarray(_dense_index(seed=7)[1], np.float32))
    plane.drain()
    assert t.result.reason == "certified"
    epochs = _events(obs, "ticket.epoch", t.trace_id)
    assert epochs
    tags = [e["attrs"]["store_epoch"] for e in epochs]
    term = _events(obs, "plane.terminal", t.trace_id)[0]
    if mode == "complete":
        assert set(tags) == {epoch0}
        assert term["attrs"]["store_epoch"] == epoch0
        assert not _events(obs, "plane.readmit", t.trace_id)
    else:
        readmits = _events(obs, "plane.readmit", t.trace_id)
        assert len(readmits) == 1
        cut = readmits[0]["ts"]
        for e in epochs:
            want = epoch0 if e["ts"] < cut else idx.epoch
            assert e["attrs"]["store_epoch"] == want, (e, cut)
        assert term["attrs"]["store_epoch"] == idx.epoch
    assert t.result.epoch == term["attrs"]["store_epoch"]


# ---------------------------------------------------------------------------
# satellite regressions: latency window + stats plumbing
# ---------------------------------------------------------------------------


def test_empty_window_percentiles_are_zero_not_nan():
    idx, _ = _dense_index()
    plane = RequestPlane(idx, obs=ObsContext("t"))
    st = plane.stats                      # zero terminals recorded
    for v in (st.plane_latency_p50_ms, st.plane_latency_p95_ms,
              st.plane_latency_p99_ms):
        assert v == 0.0 and not math.isnan(v)
    d = st.as_dict()
    assert d["plane_latency_p99_ms"] == 0.0


def test_latency_window_is_bounded_and_configurable():
    idx, queries = _dense_index()
    obs = ObsContext("t")
    plane = RequestPlane(idx, PlaneConfig(latency_window=2), obs=obs)
    for i in range(4):
        plane.query(queries, rng=jax.random.PRNGKey(i), cache="bypass")
    assert len(plane._latencies) == 2     # saturated at the window
    st = plane.stats
    assert st.plane_latency_p99_ms >= st.plane_latency_p50_ms >= 0.0
    assert not math.isnan(st.plane_latency_p99_ms)
    # the registry histogram saw ALL terminals, not just the window
    assert st.obs_latency_ms["count"] == 4
    with pytest.raises(ValueError, match="latency_window"):
        PlaneConfig(latency_window=0)


def test_stats_surface_obs_fields_and_counter_parity():
    idx, queries = _dense_index()
    obs = ObsContext("t")
    plane = RequestPlane(idx, obs=obs)
    plane.query(queries, rng=jax.random.PRNGKey(1), cache="bypass")
    st = plane.stats
    assert st.plane_submitted == st.plane_completed == 1
    assert st.obs_events == obs.events.total > 0
    assert st.obs_event_drops == 0
    assert st.obs_epoch_ms["count"] >= 1
    # the registry is the single source of truth: the exported text agrees
    text = prometheus_text(obs.registry)
    assert f'repro_plane_submitted_total{{plane="{plane.plane_id}"}} 1' \
        in text.splitlines()


# ---------------------------------------------------------------------------
# structured logging
# ---------------------------------------------------------------------------


def test_structured_logger_bind_and_suffix():
    # the repo logger installs its own handler with propagate=False, so
    # capture through a handler on the underlying logger, not caplog
    from repro.utils.logging import get_logger
    log = get_logger("repro.test_obs")
    records = []

    class _Cap(logging.Handler):
        def emit(self, record):
            records.append(record.getMessage())

    cap = _Cap(level=logging.INFO)
    log.logger.addHandler(cap)
    try:
        bound = log.bind(trace_id="p0.t1", plane="p0")
        assert bound is not log           # bind is pure
        bound.info("hello %d", 7)
        log.info("plain")
        # None-valued context is dropped, chained binds merge
        bound.bind(shard=None, epoch=2).info("x")
    finally:
        log.logger.removeHandler(cap)
    assert any("hello 7" in m and "trace_id=p0.t1" in m and "plane=p0" in m
               for m in records)
    assert any(m == "plain" for m in records)
    tail = records[-1]
    assert "epoch=2" in tail and "trace_id=p0.t1" in tail \
        and "shard" not in tail


def test_loglevel_env_reread_per_get_logger(monkeypatch):
    from repro.utils.logging import get_logger
    monkeypatch.setenv("REPRO_LOGLEVEL", "ERROR")
    lg = get_logger("repro.test_obs_lvl")
    assert lg.logger.level == logging.ERROR
    monkeypatch.setenv("REPRO_LOGLEVEL", "DEBUG")
    lg = get_logger("repro.test_obs_lvl")  # re-read, same logger object
    assert lg.logger.level == logging.DEBUG
    monkeypatch.setenv("REPRO_LOGLEVEL", "bogus")
    assert get_logger("repro.test_obs_lvl").logger.level == logging.INFO


# ---------------------------------------------------------------------------
# trace_view: chrome writer + committed sample render
# ---------------------------------------------------------------------------


def _trace_view():
    sys.path.insert(0, os.path.join(ROOT, "tools"))
    import trace_view
    return trace_view


def test_chrome_trace_writer_well_formed():
    idx, queries = _dense_index()
    obs = ObsContext("t")
    plane = RequestPlane(idx, obs=obs)
    plane.query(queries, rng=jax.random.PRNGKey(1), cache="bypass")
    doc = events_doc(obs)
    chrome = _trace_view().to_chrome(doc)
    evs = chrome["traceEvents"]
    assert evs and chrome["displayTimeUnit"] == "ms"
    names = collections.Counter(e["ph"] for e in evs)
    assert names["M"] >= 2                # one thread_name row per trace id
    assert names["X"] >= 1 and names["i"] >= 1
    for e in evs:
        if e["ph"] == "M":
            continue
        assert e["ts"] >= 0.0             # rebased to the earliest event
        if e["ph"] == "X":
            assert e["dur"] >= 0.0
        else:
            assert e["s"] == "t"
    json.dumps(chrome)                    # serializable as-is


def test_committed_sample_trace_renders():
    """Acceptance: a single plane-served query is reconstructable offline —
    the committed sample (sharded S=4 run) renders per-epoch pulls /
    frontier / CI and per-shard timing through tools/trace_view.py."""
    tv = _trace_view()
    path = os.path.join(ROOT, "examples", "sample_trace.json")
    doc = tv.load_trace(path)
    text = tv.render(doc)
    assert "plane.submit" in text and "plane.admit" in text
    assert "plane.terminal" in text
    assert "race.epoch" in text
    assert "worst_ci=" in text and "coord_ops=" in text
    assert "shard_coord_ops=" in text     # per-shard straggler split
    assert "unjoined sessions" not in text
    chrome = tv.to_chrome(doc)
    assert chrome["traceEvents"]
    with pytest.raises(ValueError, match="events"):
        tv.load_trace(os.path.join(ROOT, "tests", "api_surface.json"))


# ---------------------------------------------------------------------------
# kernel accounting
# ---------------------------------------------------------------------------


def test_kernel_launch_and_coord_op_counters():
    obs = ObsContext("t")
    idx, queries = _dense_index()
    s = idx.race(queries, jax.random.PRNGKey(0), obs=obs)
    while s.step():
        pass
    series = {(m.name, dict(m.labels).get("kernel")): m.value
              for m in obs.registry.collect()
              if m.name.startswith("repro_kernel_")}
    launches = series.get(("repro_kernel_launches_total",
                           "fused_epoch_pull"), 0)
    coord = series.get(("repro_kernel_coord_ops_total",
                        "fused_epoch_pull"), 0)
    assert launches >= 1
    assert coord > 0
    # per-launch accounting matches the session's own cumulative counter
    total = float(np.sum(s.snapshot.coord_ops))
    assert coord <= total                 # init pulls excluded from epochs

    obs2 = ObsContext("t2")
    sidx, sq = _sparse_index()
    s2 = sidx.race(sq, jax.random.PRNGKey(0), obs=obs2)
    while s2.step():
        pass
    series2 = {dict(m.labels).get("kernel") for m in
               obs2.registry.collect()
               if m.name == "repro_kernel_launches_total"}
    assert "block_pull_multi" in series2


# ---------------------------------------------------------------------------
# jax compile telemetry (repro_xla_compiles_total)
# ---------------------------------------------------------------------------


def test_xla_compile_counter_counts_fresh_compiles():
    """The jax.monitoring hook lands backend compiles in whatever obs
    context is CURRENT at compile time — test-injected contexts see
    exactly the compiles their own traffic caused."""
    from repro.obs import compiles_total, set_obs

    ctx = ObsContext("compiles")
    old = set_obs(ctx)
    try:
        idx, queries = _dense_index(n=128, d=256, seed=7)
        idx.query(queries, jax.random.PRNGKey(0))
        fresh = compiles_total(ctx)
    finally:
        set_obs(old)
    assert fresh >= 1                     # build + first race compile
    h = ctx.registry.histogram("repro_xla_compile_ms",
                               "XLA backend compile wall time (ms)")
    assert h.count == fresh and h.sum > 0.0


def test_warm_race_precompile_leaves_zero_midtraffic_compiles():
    """Regression gate for the warm-start compile chain (DESIGN.md §9):
    the pow2 survivor buckets and pow2-quantized adaptive R bound the
    reachable (W, R) specializations to a log²-sized set, so a handful of
    full-certification warm races must exhaust it — and same-shape
    traffic after that must trigger ZERO further XLA backend compiles.
    An unbounded specialization chain (e.g. un-quantized adaptive R)
    never goes quiet and fails the convergence budget."""
    from repro.obs import compiles_total, set_obs

    idx, queries = _dense_index(n=256, d=256, seed=3)

    def one_race(i):
        rng = np.random.default_rng(i)
        qs = (np.asarray(queries)
              + rng.normal(size=np.asarray(queries).shape)
              .astype(np.float32))
        ctx = ObsContext(f"race{i}")
        old = set_obs(ctx)
        try:
            idx.query(qs, jax.random.PRNGKey(i), cache="bypass")
        finally:
            set_obs(old)
        return compiles_total(ctx)

    # warm until the chain is exhausted (two consecutive quiet races)
    quiet, budget = 0, 12
    for i in range(budget):
        quiet = quiet + 1 if one_race(i) == 0 else 0
        if quiet >= 2:
            break
    assert quiet >= 2, (
        f"compile chain did not converge within {budget} warm races — "
        "specializations are no longer bounded")
    # ...and stays exhausted: mid-traffic races compile NOTHING
    mid = sum(one_race(100 + j) for j in range(3))
    assert mid == 0, (
        f"{mid} XLA compile(s) fired mid-traffic after a warm race — "
        "the precompile chain no longer covers serving shapes")


def test_prometheus_exposition_scraper_conformance():
    """Satellite (PR 8): parse the exposition text the way a scraper does
    and enforce the 0.0.4 grammar — all series of one name contiguous even
    when registration interleaves names, exactly one # TYPE per group
    emitted before any of its samples, HELP/label escaping, cumulative
    monotone ``le`` buckets ending at +Inf == _count, and a _sum sample."""
    obs = ObsContext("t")
    reg = obs.registry
    # interleave registrations across names and label sets on purpose
    reg.counter("repro_x_total", "x events", tenant="a").inc(1)
    h = reg.histogram("repro_ms", "hist with \\ backslash\nnewline",
                      buckets=(1.0, 5.0), tenant="a")
    reg.counter("repro_x_total", "x events", tenant='we"ird\none').inc(2)
    reg.gauge("repro_g", "a gauge").set(1.5)
    h2 = reg.histogram("repro_ms", "", buckets=(1.0, 5.0), tenant="b")
    for v in (0.5, 2.0, 50.0):
        h.observe(v)
    h2.observe(0.1)
    text = prometheus_text(reg)
    assert text.endswith("\n")

    seen_groups, cur = [], None
    types, samples = {}, collections.defaultdict(list)
    for line in text.splitlines():
        assert line == line.strip() and line
        if line.startswith("# HELP "):
            _, name, help_text = line.split(" ", 2)
            assert "\n" not in help_text        # escaped, single line
            continue
        if line.startswith("# TYPE "):
            _, _, name, kind = line.split(" ")
            assert name not in types, f"duplicate TYPE for {name}"
            types[name] = kind
            seen_groups.append(name)
            cur = name
            continue
        sample, value = line.rsplit(" ", 1)
        base = sample.split("{")[0]
        for suffix in ("_bucket", "_sum", "_count"):
            if base.endswith(suffix) and \
                    base[: -len(suffix)] in types:
                base = base[: -len(suffix)]
                break
        assert base == cur, f"sample {line!r} outside its TYPE group"
        assert base in types, f"sample before TYPE: {line!r}"
        samples[sample.split(" ")[0]].append(float(value))
        samples[base].append(float(value))
    # contiguous: each name opened exactly one group (the context itself
    # eagerly registers its ring-drop counter, hence the leading entry)
    assert seen_groups == ["repro_obs_event_drops_total", "repro_x_total",
                           "repro_ms", "repro_g"]
    assert types == {"repro_obs_event_drops_total": "counter",
                     "repro_x_total": "counter", "repro_ms": "histogram",
                     "repro_g": "gauge"}
    # escaped label value survives as one line
    assert 'tenant="we\\"ird\\none"' in text
    assert "repro_ms hist with \\\\ backslash\\nnewline" in text
    # per-series buckets: cumulative, monotone, +Inf == _count
    for tenant, (c1, c5, cinf, total) in (("a", (1, 2, 3, 3)),
                                          ("b", (1, 1, 1, 1))):
        pre = f'repro_ms_bucket{{tenant="{tenant}",'
        bucket_lines = [l for l in text.splitlines() if l.startswith(pre)]
        vals = [int(l.rsplit(" ", 1)[1]) for l in bucket_lines]
        assert vals == sorted(vals) == [c1, c5, cinf]
        assert f'repro_ms_count{{tenant="{tenant}"}} {total}' in text
        assert any(l.startswith(f'repro_ms_sum{{tenant="{tenant}"}} ')
                   for l in text.splitlines())


def test_event_ring_overflow_exports_drop_counter_and_warns_once():
    """Satellite (PR 8): ring overflow is a first-class signal — the drop
    count exports as ``repro_obs_event_drops_total`` and the first
    overflow warns through the structured logger exactly once."""
    from repro.utils.logging import get_logger
    records = []

    class _Cap(logging.Handler):
        def emit(self, record):
            records.append(record.getMessage())

    cap = _Cap(level=logging.WARNING)
    lg = get_logger("repro.obs")
    lg.logger.addHandler(cap)
    try:
        obs = ObsContext("ovf", event_capacity=4, enabled=True)
        for i in range(3):
            obs.tracer.instant(f"e{i}", trace="t")
        drops = [m for m in obs.registry.collect()
                 if m.name == "repro_obs_event_drops_total"]
        assert len(drops) == 1 and drops[0].value == 0
        assert dict(drops[0].labels)["ring"] == "ovf"
        assert not records                       # no overflow yet, no noise
        for i in range(6):
            obs.tracer.instant(f"f{i}", trace="t")
        assert obs.events.drops == 5
        assert drops[0].value == 5               # counter tracks the ring
        warned = [m for m in records if "ring=ovf" in m]
        assert len(warned) == 1                  # warn-once, not per-event
        assert "4" in warned[0]                  # names the capacity
    finally:
        lg.logger.removeHandler(cap)
    # the Prometheus view carries it too
    assert 'repro_obs_event_drops_total{ring="ovf"} 5' in \
        prometheus_text(obs.registry)
