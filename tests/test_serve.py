"""Serving engine: batched generation, kNN-LM retrieval hook, whisper."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.configs.base import BMOConfig
from repro.models import build_model
from repro.serve.engine import KNNLMConfig, ServeEngine
from repro.sharding.spec import init_params


def _mesh():
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def _engine(arch="qwen2.5-14b", knn=False, batch=2, max_seq=48):
    entry = get_arch(arch)
    cfg = entry.smoke
    model = build_model(cfg)
    plan = dataclasses.replace(entry.plan, fsdp=False, tp=False, sp=False,
                               ep=False, param_dtype="float32")
    params = init_params(model.param_specs(), jax.random.PRNGKey(0))
    knn_cfg = datastore = None
    if knn:
        rng = np.random.default_rng(0)
        keys = rng.normal(size=(128, cfg.d_model)).astype(np.float32)
        ids = rng.integers(0, cfg.vocab_size, 128).astype(np.int32)
        datastore = (jnp.asarray(keys), jnp.asarray(ids))
        knn_cfg = KNNLMConfig(lam=0.3, bmo=BMOConfig(
            k=4, delta=0.1, block=16, batch_arms=8, metric="l2"))
    return ServeEngine(model, params, plan, _mesh(), batch_size=batch,
                       max_seq=max_seq, knn_lm=knn_cfg, datastore=datastore), cfg


def test_generate_shapes():
    engine, cfg = _engine()
    prompts = np.random.default_rng(1).integers(0, cfg.vocab_size, (2, 8)).astype(np.int32)
    out, _ = engine.generate(prompts, 6)
    assert out.shape == (2, 6)
    assert (out >= 0).all() and (out < cfg.vocab_size).all()


def test_generate_greedy_deterministic():
    engine, cfg = _engine()
    prompts = np.random.default_rng(2).integers(0, cfg.vocab_size, (2, 8)).astype(np.int32)
    out1, _ = engine.generate(prompts, 5)
    engine2, _ = _engine()
    out2, _ = engine2.generate(prompts, 5)
    np.testing.assert_array_equal(out1, out2)


def test_generate_matches_stepwise_forward():
    """Engine tokens == naive full-recompute greedy decoding."""
    engine, cfg = _engine(max_seq=32)
    entry = get_arch("qwen2.5-14b")
    model = build_model(entry.smoke)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0))
    prompts = np.random.default_rng(3).integers(0, cfg.vocab_size, (2, 6)).astype(np.int32)
    out, _ = engine.generate(prompts, 4)
    toks = jnp.asarray(prompts)
    for t in range(4):
        logits, _ = model.apply(params, {"tokens": toks}, remat="none")
        nxt = jnp.argmax(logits[:, -1].astype(jnp.float32), -1).astype(jnp.int32)
        np.testing.assert_array_equal(np.asarray(nxt), out[:, t])
        toks = jnp.concatenate([toks, nxt[:, None]], axis=1)


def test_knn_lm_hook_runs_and_counts_ops():
    engine, cfg = _engine(knn=True)
    prompts = np.random.default_rng(4).integers(0, cfg.vocab_size, (2, 8)).astype(np.int32)
    out, retrieval_ops = engine.generate(prompts, 4)
    assert out.shape == (2, 4)
    assert retrieval_ops > 0  # BMO retrieval actually sampled coordinates
