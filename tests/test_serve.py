"""Serving engine: batched generation, kNN-LM retrieval hook, whisper."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.configs.base import BMOConfig
from repro.models import build_model
from repro.serve.engine import KNNLMConfig, ServeEngine
from repro.sharding.spec import init_params


def _mesh():
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def _engine(arch="qwen2.5-14b", knn=False, batch=2, max_seq=48):
    entry = get_arch(arch)
    cfg = entry.smoke
    model = build_model(cfg)
    plan = dataclasses.replace(entry.plan, fsdp=False, tp=False, sp=False,
                               ep=False, param_dtype="float32")
    params = init_params(model.param_specs(), jax.random.PRNGKey(0))
    knn_cfg = datastore = None
    if knn:
        rng = np.random.default_rng(0)
        keys = rng.normal(size=(128, cfg.d_model)).astype(np.float32)
        ids = rng.integers(0, cfg.vocab_size, 128).astype(np.int32)
        datastore = (jnp.asarray(keys), jnp.asarray(ids))
        knn_cfg = KNNLMConfig(lam=0.3, bmo=BMOConfig(
            k=4, delta=0.1, block=16, batch_arms=8, metric="l2"))
    return ServeEngine(model, params, plan, _mesh(), batch_size=batch,
                       max_seq=max_seq, knn_lm=knn_cfg, datastore=datastore), cfg


def test_generate_shapes():
    engine, cfg = _engine()
    prompts = np.random.default_rng(1).integers(0, cfg.vocab_size, (2, 8)).astype(np.int32)
    out, _ = engine.generate(prompts, 6)
    assert out.shape == (2, 6)
    assert (out >= 0).all() and (out < cfg.vocab_size).all()


def test_generate_greedy_deterministic():
    engine, cfg = _engine()
    prompts = np.random.default_rng(2).integers(0, cfg.vocab_size, (2, 8)).astype(np.int32)
    out1, _ = engine.generate(prompts, 5)
    engine2, _ = _engine()
    out2, _ = engine2.generate(prompts, 5)
    np.testing.assert_array_equal(out1, out2)


def test_generate_matches_stepwise_forward():
    """Engine tokens == naive full-recompute greedy decoding."""
    engine, cfg = _engine(max_seq=32)
    entry = get_arch("qwen2.5-14b")
    model = build_model(entry.smoke)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0))
    prompts = np.random.default_rng(3).integers(0, cfg.vocab_size, (2, 6)).astype(np.int32)
    out, _ = engine.generate(prompts, 4)
    toks = jnp.asarray(prompts)
    for t in range(4):
        logits, _ = model.apply(params, {"tokens": toks}, remat="none")
        nxt = jnp.argmax(logits[:, -1].astype(jnp.float32), -1).astype(jnp.int32)
        np.testing.assert_array_equal(np.asarray(nxt), out[:, t])
        toks = jnp.concatenate([toks, nxt[:, None]], axis=1)


def test_knn_lm_hook_runs_and_counts_ops():
    engine, cfg = _engine(knn=True)
    prompts = np.random.default_rng(4).integers(0, cfg.vocab_size, (2, 8)).astype(np.int32)
    out, retrieval_ops = engine.generate(prompts, 4)
    assert out.shape == (2, 4)
    assert retrieval_ops > 0  # BMO retrieval actually sampled coordinates


def test_query_cache_serves_repeats_for_free():
    """Repeat queries hit the LRU: zero coordinate-ops, identical top-k,
    typed counters surfaced in engine stats — both as ServeStats attributes
    and through the legacy stringly keys (ROADMAP: query cache)."""
    engine, cfg = _engine(knn=True)
    hidden = jnp.asarray(np.random.default_rng(7).normal(
        size=(2, cfg.d_model)).astype(np.float32))
    logits1, ops1 = engine._knn_logits(hidden, jax.random.PRNGKey(0))
    assert ops1 > 0
    st = engine.stats
    assert st.cache_misses == 2 and st.cache_hits == 0
    assert st["knn_races"] == 1 and st["knn_raced_queries"] == 2

    # different rng — must not matter, results come from the cache
    logits2, ops2 = engine._knn_logits(hidden, jax.random.PRNGKey(9))
    assert ops2 == 0.0
    st = engine.stats
    assert st["knn_cache_hits"] == 2 and st.races == 1
    np.testing.assert_array_equal(np.asarray(logits1), np.asarray(logits2))

    # partial repeat: one cached row, one new row → only the miss races
    hidden2 = jnp.concatenate([hidden[:1], hidden[1:] + 1.0], axis=0)
    _, ops3 = engine._knn_logits(hidden2, jax.random.PRNGKey(1))
    assert ops3 > 0
    st = engine.stats
    assert st.cache_hits == 3 and st.raced_queries == 3

    # handle-side mutation (not via the engine's append) must invalidate
    # too: every mutation bumps the handle's epoch, which fences the cache
    epoch0 = engine.index.epoch
    top0 = int(engine.index.query(np.asarray(hidden)[:1],
                                  jax.random.PRNGKey(2),
                                  cache="bypass").indices[0, 0])
    engine.index.delete([top0])
    assert engine.index.epoch == epoch0 + 1
    _, ops4 = engine._knn_logits(hidden, jax.random.PRNGKey(2))
    assert ops4 > 0                       # raced fresh — no stale cache hit
    res = engine.index.query(np.asarray(hidden)[:1], jax.random.PRNGKey(3),
                             cache="bypass")
    assert top0 not in set(np.asarray(res.indices[0]).tolist())


def test_query_cache_get_near_and_eviction():
    """Near-match lookup: cosine threshold, exact-miss-only contract, and
    vector eviction riding the LRU (QueryCache now lives in repro.api;
    the engine re-exports it)."""
    from repro.serve.engine import QueryCache
    cache = QueryCache(capacity=2)
    a = np.asarray([1.0, 0.0, 0.0], np.float32)
    b = np.asarray([0.0, 1.0, 0.0], np.float32)
    cache.put(QueryCache.key(a), "A", vec=a)
    cache.put(QueryCache.key(b), "B", vec=b)
    near_a = np.asarray([0.99, 0.05, 0.0], np.float32)
    assert cache.get_near(near_a, 0.95) == "A"
    assert cache.get_near(np.asarray([1.0, 1.0, 1.0], np.float32), 0.95) is None
    assert cache.get_near(np.zeros(3, np.float32), 0.95) is None  # zero norm
    # eviction drops the vector too: A is LRU-evicted by C
    c = np.asarray([0.0, 0.0, 1.0], np.float32)
    cache.put(QueryCache.key(c), "C", vec=c)
    assert cache.get_near(near_a, 0.95) is None
    assert len(cache._vecs) == 2


def test_query_cache_zero_norm_guards():
    """Regression (PR 4 satellite): cosine lookup divides by vector norms —
    a zero (or non-finite) query vector must MISS, never NaN-match, and a
    zero-norm vector is never admitted to the near-match matrix."""
    from repro.api import QueryCache
    cache = QueryCache(capacity=4)
    a = np.asarray([1.0, 0.0, 0.0], np.float32)
    cache.put(QueryCache.key(a), "A", vec=a)
    zero = np.zeros(3, np.float32)
    with np.errstate(all="raise"):        # any divide/invalid would raise
        assert cache.get_near(zero, 0.95) is None
        assert cache.get_near(np.asarray([np.nan] * 3, np.float32),
                              0.95) is None
    # a zero-vector put stays servable by exact key but never near-matches
    cache.put(QueryCache.key(zero), "Z", vec=zero)
    assert cache.get(QueryCache.key(zero)) == "Z"
    assert QueryCache.key(zero) not in cache._vecs
    with np.errstate(all="raise"):
        assert cache.get_near(np.asarray([0.0, 1.0, 0.0], np.float32),
                              0.95) is None


def test_near_repeat_seeds_priors_and_counts(monkeypatch):
    """A near-repeat query (cosine ≥ threshold to a cached one) still races
    — it is a cache miss — but its CI priors are seeded from the cached
    neighbour's result: near_hits counts it, a per-query prior_hint reaches
    the racing driver, and the top-k is still exact (ROADMAP: near-repeat
    warm-starts)."""
    engine, cfg = _engine(knn=True)
    hidden = jnp.asarray(np.random.default_rng(9).normal(
        size=(2, cfg.d_model)).astype(np.float32))
    engine._knn_logits(hidden, jax.random.PRNGKey(0))       # fill the cache
    assert engine.stats.near_hits == 0

    seen_hints = []
    import repro.api.handle as handle_mod
    real_index_knn = handle_mod._index_knn

    def spy(store, queries, rng, **kw):
        seen_hints.append(kw.get("prior_hint"))
        return real_index_knn(store, queries, rng, **kw)

    # Index.query races through the one seam in repro.api.handle
    monkeypatch.setattr(handle_mod, "_index_knn", spy)

    near = np.asarray(hidden, np.float32).copy()
    near[0] *= 1.0 + 1e-4                    # same direction, new bytes
    res = engine.index.query(near[:1], jax.random.PRNGKey(1))
    st = engine.stats
    assert st["knn_near_hits"] == 1
    assert float(res.coord_ops.sum()) > 0    # raced, not short-circuited
    hint = seen_hints[-1]
    assert hint is not None and hint.shape[1] == engine.index.capacity
    # the cached neighbour's arms got tightened priors, others kept base
    base = np.asarray(engine.index.store.prior_var, np.float32)
    tightened = np.nonzero(hint[0] < base - 1e-12)[0]
    cache = engine.index._cache
    cached_idx, _ = cache.get(cache.key(np.asarray(hidden, np.float32)[0]))
    assert set(tightened.tolist()) <= set(np.asarray(cached_idx).tolist())
    # scaling ~ (1e-4 perturbation) keeps the true top-k unchanged
    from repro.core import oracle
    keys = np.asarray(np.random.default_rng(0).normal(
        size=(128, cfg.d_model)), np.float32)
    ex = oracle.exact_knn(keys, near[:1], 4, "l2")
    assert set(res.indices[0].tolist()) == \
        set(np.asarray(ex.indices[0]).tolist())


def test_index_append_invalidates_cache_and_auto_compacts():
    """Decode-time appends invalidate cached top-k; tombstone debt crossing
    the CompactionPolicy threshold triggers auto-compaction with the
    handle's automatic payload remapping."""
    engine, cfg = _engine(knn=True)
    hidden = jnp.asarray(np.random.default_rng(8).normal(
        size=(2, cfg.d_model)).astype(np.float32))
    engine._knn_logits(hidden, jax.random.PRNGKey(0))
    assert engine.stats.cache_entries == 2

    # tombstone 100 of 128 slots, then append: fraction crosses 0.5
    engine.index.delete(list(range(20, 120)))
    tok = np.asarray([[1], [2]], np.int32)
    before = engine.index.payload.copy()
    engine._append_to_index(np.asarray(hidden), tok)
    st = engine.stats
    assert st["index_compactions"] == 1
    assert engine.stats.cache_entries == 0            # invalidated
    assert engine.index.capacity == 32                # 30 live → pow2 cover
    assert engine.index.n_live == 30
    # the payload rode along: compaction keeps live slots in ascending
    # order, so old slots 0..19 land on new slots 0..19 and the two rows
    # appended into freed slots follow
    payload = engine.index.payload
    assert len(payload) == engine.index.capacity
    np.testing.assert_array_equal(payload[:20], before[:20])
    assert set(payload[20:22].tolist()) == {1, 2}
    # retrieval still works end-to-end on the compacted index
    logits, ops = engine._knn_logits(hidden, jax.random.PRNGKey(2))
    assert np.isfinite(np.asarray(logits)).all() and ops > 0
