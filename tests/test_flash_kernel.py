"""Pallas fused flash-attention kernel vs the SDPA oracle (interpret mode),
swept over shapes / causality / offsets / dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attn import flash_attention_pallas
from repro.models.common import _sdpa


def _run(rng, B, H, KV, Sq, Sk, D, causal, off, bq=64, bk=64, dtype=jnp.float32):
    q = jnp.asarray(rng.normal(size=(B, Sq, H, D)).astype(np.float32)).astype(dtype)
    k = jnp.asarray(rng.normal(size=(B, Sk, KV, D)).astype(np.float32)).astype(dtype)
    v = jnp.asarray(rng.normal(size=(B, Sk, KV, D)).astype(np.float32)).astype(dtype)
    want = _sdpa(q.astype(jnp.float32), k.astype(jnp.float32),
                 v.astype(jnp.float32), causal=causal, q_offset=off)
    G = H // KV
    got = flash_attention_pallas(
        q.transpose(0, 2, 1, 3),
        jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1),
        jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1),
        causal=causal, q_offset=off, bq=bq, bk=bk, interpret=True)
    return np.asarray(got.transpose(0, 2, 1, 3), np.float32), np.asarray(want)


@pytest.mark.parametrize("B,H,KV,Sq,Sk,D,causal,off", [
    (2, 4, 4, 128, 128, 32, True, 0),
    (1, 2, 2, 64, 256, 16, True, 192),     # decode-ish: q at cache tail
    (2, 4, 2, 128, 128, 32, True, 0),      # GQA
    (2, 4, 4, 128, 128, 32, False, 0),     # bidirectional
    (1, 1, 1, 64, 64, 128, True, 0),
])
def test_flash_kernel_matches_sdpa(rng, B, H, KV, Sq, Sk, D, causal, off):
    got, want = _run(rng, B, H, KV, Sq, Sk, D, causal, off)
    np.testing.assert_allclose(got, want, atol=3e-5)


@pytest.mark.parametrize("bq,bk", [(32, 64), (64, 32), (128, 128)])
def test_flash_kernel_block_shapes(rng, bq, bk):
    got, want = _run(rng, 1, 2, 2, 128, 128, 32, True, 0, bq=bq, bk=bk)
    np.testing.assert_allclose(got, want, atol=3e-5)


def test_flash_kernel_bf16(rng):
    got, want = _run(rng, 1, 2, 2, 64, 64, 32, True, 0, dtype=jnp.bfloat16)
    np.testing.assert_allclose(got, want, atol=3e-2, rtol=3e-2)


def test_model_attn_impl_pallas_matches_xla(rng):
    """End-to-end: a DenseLM forward with attn_impl='pallas' (fused kernel,
    interpret on CPU) matches the XLA attention path."""
    import dataclasses
    import jax
    from repro.configs import get_arch
    from repro.models import build_model
    from repro.sharding.spec import init_params

    entry = get_arch("qwen2.5-14b")
    toks = jnp.asarray(rng.integers(0, 256, (2, 128)), jnp.int32)
    outs = {}
    for impl in ("auto", "pallas"):
        # fp32 compute isolates the kernel from bf16 accumulation noise
        cfg = dataclasses.replace(entry.smoke, attn_impl=impl, head_dim=32)
        model = build_model(cfg)
        params = init_params(model.param_specs(), jax.random.PRNGKey(0))
        logits, _ = model.apply(params, {"tokens": toks}, remat="none",
                                compute_dtype=jnp.float32)
        outs[impl] = np.asarray(logits.astype(jnp.float32))
    np.testing.assert_allclose(outs["pallas"], outs["auto"], atol=1e-3,
                               rtol=1e-3)
