"""Minimal stand-in for ``hypothesis`` when it isn't installed (the pinned
container has no network access, so property tests fall back to seeded random
sampling over the same strategy ranges).

Covers exactly the surface this test suite uses: ``given``, ``settings``,
``strategies.{integers,floats,booleans,lists,sampled_from}``. Examples are
drawn from a per-test deterministic generator so failures reproduce.
"""
from __future__ import annotations

import zlib

import numpy as np

DEFAULT_MAX_EXAMPLES = 25


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: np.random.Generator):
        return self._draw(rng)


class strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: bool(rng.integers(0, 2)))

    @staticmethod
    def sampled_from(options):
        options = list(options)
        return _Strategy(lambda rng: options[int(rng.integers(0, len(options)))])

    @staticmethod
    def lists(elements, min_size=0, max_size=10):
        def draw(rng):
            size = int(rng.integers(min_size, max_size + 1))
            return [elements.draw(rng) for _ in range(size)]

        return _Strategy(draw)


def settings(max_examples=DEFAULT_MAX_EXAMPLES, **_ignored):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco


def given(*strats):
    def deco(fn):
        inner = fn

        # NB: zero-arg wrapper with no __wrapped__, so pytest does not try
        # to resolve the strategy-filled parameters as fixtures
        def wrapper():
            n = getattr(wrapper, "_stub_max_examples", DEFAULT_MAX_EXAMPLES)
            # crc32, not hash(): str hashing is salted per process, and the
            # whole point is that a failing draw reproduces across runs
            seed = zlib.crc32(
                f"{inner.__module__}.{inner.__qualname__}".encode())
            rng = np.random.default_rng(seed)
            for i in range(n):
                drawn = tuple(s.draw(rng) for s in strats)
                try:
                    inner(*drawn)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example #{i}: {drawn!r}") from e

        for attr in ("__name__", "__qualname__", "__module__", "__doc__"):
            setattr(wrapper, attr, getattr(fn, attr))
        return wrapper

    return deco
