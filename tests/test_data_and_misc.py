"""Data-pipeline determinism, input_specs coverage, misc substrate tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, get_arch
from repro.data.synthetic import clustered_dense, clustered_sparse, lm_batch
from repro.models import build_model


def test_lm_batch_deterministic():
    a = lm_batch(1000, 4, 32, seed=7, step=123)
    b = lm_batch(1000, 4, 32, seed=7, step=123)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])
    c = lm_batch(1000, 4, 32, seed=7, step=124)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_lm_batch_labels_are_shifted():
    a = lm_batch(1000, 2, 16, seed=1, step=0)
    # labels[t] is the next token of an underlying (seq+1) stream; check
    # alignment: tokens[1:] == labels[:-1]
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_clustered_dense_shape_and_variance():
    x = clustered_dense(100, 64, n_clusters=4, seed=0)
    assert x.shape == (100, 64) and x.dtype == np.float32
    assert np.isfinite(x).all()


def test_clustered_sparse_sparsity():
    x = clustered_sparse(200, 512, sparsity=0.07, seed=0)
    frac = (x != 0).mean()
    assert 0.02 < frac < 0.15
    assert (x >= 0).all()


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("shape_name", list(SHAPES))
def test_input_specs_all_cells(arch, shape_name):
    """input_specs must be well-defined for every (arch × shape) cell —
    ShapeDtypeStructs only, no allocation."""
    from repro.launch import dryrun
    specs = dryrun.input_specs(arch, shape_name)
    assert isinstance(specs, dict) and specs
    for k, v in specs.items():
        assert isinstance(v, jax.ShapeDtypeStruct), (k, type(v))
        assert all(d > 0 for d in v.shape)


def test_shape_table():
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].seq_len == 32768
    assert SHAPES["decode_32k"].kind == "decode"
    assert SHAPES["long_500k"].seq_len == 524288


def test_registry_covers_all_archs():
    assert len(ARCHS) == 10
    for a in ARCHS:
        e = get_arch(a)
        assert e.config.name == a
        assert e.smoke.d_model <= 128  # genuinely reduced


def test_shard_act_noop_outside_context(rng):
    from repro.sharding.context import shard_act
    x = jnp.asarray(rng.normal(size=(2, 3, 4)).astype(np.float32))
    np.testing.assert_array_equal(np.asarray(shard_act(x)), np.asarray(x))


def test_tree_utils(rng):
    from repro.utils.tree import tree_bytes, tree_count
    t = {"a": jnp.zeros((3, 4), jnp.float32), "b": jnp.zeros((5,), jnp.bfloat16)}
    assert tree_count(t) == 17
    assert tree_bytes(t) == 3 * 4 * 4 + 5 * 2


def test_roofline_table_renders(tmp_path):
    import json
    from benchmarks.roofline_table import load, markdown_table
    p = tmp_path / "r.jsonl"
    rec = {"arch": "x", "shape": "train_4k", "mesh": "single",
           "variant": "baseline", "status": "ok", "t_compute": 1.0,
           "t_memory": 2.0, "t_collective": 0.5, "bottleneck": "memory",
           "useful_flops_ratio": 0.7, "roofline_fraction": 0.35,
           "peak_memory_per_chip": 2.0 * 2**30, "fits_hbm": True}
    p.write_text(json.dumps(rec) + "\n")
    rows = load(str(p))
    md = markdown_table(rows)
    assert "memory" in md and "0.3500" in md
