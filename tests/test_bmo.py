"""BMO-UCB + BMO-NN system tests: exactness vs the oracle, estimator
unbiasedness (exact enumeration), sparse box law, PAC guarantee, counting."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import BMOConfig
from repro.core import bmo_nn, oracle
from repro.core.datasets import DenseDataset, SparseDataset
from repro.data.synthetic import make_knn_benchmark_data


def _accuracy(res_idx, ex_idx):
    return float(np.mean([set(np.asarray(res_idx[i])) == set(np.asarray(ex_idx[i]))
                          for i in range(len(ex_idx))]))


# ---------------------------------------------------------------------------
# estimator unbiasedness — exact expectation over all blocks / outcomes
# ---------------------------------------------------------------------------

def test_dense_block_estimator_unbiased_exact(rng):
    """E[block pull] over the uniform block distribution == θ exactly."""
    n, d, block = 5, 512, 64
    X = rng.normal(size=(n, d)).astype(np.float32)
    q = rng.normal(size=(d,)).astype(np.float32)
    ds = DenseDataset.build(X, block)
    qp = np.asarray(ds.pad_query(jnp.asarray(q)))
    from repro.kernels import ref
    nb = ds.n_blocks
    blk = jnp.broadcast_to(jnp.arange(nb)[None], (n, nb)).astype(jnp.int32)
    pulls = ref.block_pull_ref(ds.x, jnp.asarray(qp), jnp.arange(n), blk, block)
    exp = np.asarray(pulls).mean(axis=1)          # uniform over blocks
    theta = ((X - q) ** 2).sum(1) / d
    np.testing.assert_allclose(exp, theta, rtol=1e-4)


def test_sparse_estimator_unbiased_exact_enumeration(rng):
    """Enumerate Eq. (12)'s sample space exactly: Σ p(outcome)·X == ‖·‖₁/d."""
    d = 40
    x0 = np.zeros(d, np.float32)
    xi = np.zeros(d, np.float32)
    x0[[2, 7, 11, 23]] = [1.0, -2.0, 0.5, 3.0]
    xi[[7, 11, 30]] = [4.0, 0.5, -1.5]
    ds = SparseDataset.build(xi[None])
    from repro.core.bmo_nn import _sparse_lookup
    q_nz = np.nonzero(x0)[0]
    a_nz = np.nonzero(xi)[0]
    n0, ni = len(q_nz), len(a_nz)
    tot = n0 + ni
    expectation = 0.0
    for t in q_nz:  # sampled from query side w.p. n0/tot × 1/n0
        in_other = t in a_nz
        mult = tot / (2 * d) * (1 + (not in_other))
        expectation += (1 / tot) * mult * abs(x0[t] - xi[t])
    for t in a_nz:
        in_other = t in q_nz
        mult = tot / (2 * d) * (1 + (not in_other))
        expectation += (1 / tot) * mult * abs(x0[t] - xi[t])
    theta = np.abs(x0 - xi).sum() / d
    assert expectation == pytest.approx(theta, rel=1e-6)


def test_sparse_exact_theta_matches_dense(rng):
    n, d = 12, 64
    mask = rng.random((n, d)) < 0.2
    X = np.where(mask, rng.exponential(1.0, (n, d)), 0).astype(np.float32)
    q = np.where(rng.random(d) < 0.2, rng.exponential(1.0, d), 0).astype(np.float32)
    ds = SparseDataset.build(X)
    qs = SparseDataset.build(q[None])
    got = np.asarray(bmo_nn.sparse_exact_theta(
        ds, qs.indices[0], qs.values[0], jnp.arange(n)))
    want = np.abs(X - q).sum(1) / d
    np.testing.assert_allclose(got, want, rtol=1e-5)


# ---------------------------------------------------------------------------
# exactness vs the oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("eliminate", [True, False])
def test_knn_exact_on_clustered_data(eliminate):
    corpus, queries = make_knn_benchmark_data("dense", 400, 1024, 6, seed=1)
    ex = oracle.exact_knn(corpus, queries, 3, "l2")
    cfg = BMOConfig(k=3, delta=0.01, block=64, batch_arms=16,
                    pulls_per_round=2, metric="l2")
    res = bmo_nn.knn(corpus, queries, cfg, jax.random.PRNGKey(0),
                     eliminate=eliminate)
    assert _accuracy(res.indices, ex.indices) == 1.0
    # and it must actually save coordinate computations on clustered data
    assert float(np.sum(np.asarray(res.coord_ops))) < 6 * 400 * 1024


def test_knn_rotated_exact():
    corpus, queries = make_knn_benchmark_data("dense", 300, 512, 4, seed=2)
    ex = oracle.exact_knn(corpus, queries, 3, "l2")
    cfg = BMOConfig(k=3, delta=0.01, block=64, batch_arms=16, metric="l2",
                    rotate=True)
    res = bmo_nn.knn(corpus, queries, cfg, jax.random.PRNGKey(1))
    assert _accuracy(res.indices, ex.indices) == 1.0


def test_knn_l1_metric():
    corpus, queries = make_knn_benchmark_data("dense", 200, 512, 4, seed=3)
    ex = oracle.exact_knn(corpus, queries, 2, "l1")
    cfg = BMOConfig(k=2, delta=0.01, block=64, batch_arms=16, metric="l1")
    res = bmo_nn.knn(corpus, queries, cfg, jax.random.PRNGKey(2))
    assert _accuracy(res.indices, ex.indices) == 1.0


def test_knn_sparse_exact():
    from repro.data.synthetic import clustered_sparse
    corpus = clustered_sparse(200, 2048, seed=4)
    ds = SparseDataset.build(corpus)
    qi, qv, qn = ds.indices[:4], ds.values[:4], ds.nnz[:4]
    ex = oracle.exact_knn_sparse(ds, qi, qv, qn, 3)
    cfg = BMOConfig(k=3, delta=0.01, block=1, batch_arms=16,
                    pulls_per_round=8, init_pulls=16, metric="l1", sparse=True)
    res = bmo_nn.knn(ds, (qi, qv, qn), cfg, jax.random.PRNGKey(3))
    assert _accuracy(res.indices, ex.indices) == 1.0


def test_knn_graph_drops_self():
    corpus, _ = make_knn_benchmark_data("dense", 64, 256, 1, seed=5)
    cfg = BMOConfig(k=2, delta=0.05, block=32, batch_arms=16, metric="l2")
    res = bmo_nn.knn_graph(corpus, cfg, jax.random.PRNGKey(4))
    idx = np.asarray(res.indices)
    assert idx.shape == (64, 2)
    for i in range(64):
        assert i not in idx[i]


# ---------------------------------------------------------------------------
# PAC variant (Theorem 2)
# ---------------------------------------------------------------------------

def test_pac_epsilon_guarantee(rng):
    n, d, Q = 300, 2048, 6
    X = rng.normal(size=(n, d)).astype(np.float32)
    qs = X[:Q] + 0.02 * rng.normal(size=(Q, d)).astype(np.float32)
    eps = 0.5
    ex = oracle.exact_knn(X, qs, 1, "l2")
    cfg = BMOConfig(k=1, delta=0.01, block=128, batch_arms=16, metric="l2",
                    epsilon=eps)
    res = bmo_nn.knn(X, qs, cfg, jax.random.PRNGKey(5))
    for i in range(Q):
        got = int(res.indices[i, 0])
        theta = float(((qs[i] - X[got]) ** 2).sum() / d)
        assert theta <= float(ex.values[i, 0]) + eps + 1e-6
    # PAC should use fewer ops than the exact-k run on this hard instance
    cfg_exact = dataclasses.replace(cfg, epsilon=0.0)
    res_exact = bmo_nn.knn(X, qs, cfg_exact, jax.random.PRNGKey(5))
    assert float(np.sum(np.asarray(res.coord_ops))) <= \
        float(np.sum(np.asarray(res_exact.coord_ops)))


# ---------------------------------------------------------------------------
# cost accounting invariants
# ---------------------------------------------------------------------------

def test_coord_ops_bounded_by_2nd_plus_init():
    """Paper: 'even if the algorithm fails it will not take more than 2nd
    coordinate-wise distance computations' (+ our batched-round slack)."""
    corpus, queries = make_knn_benchmark_data("dense", 100, 512, 3, seed=6)
    cfg = BMOConfig(k=3, delta=0.01, block=64, batch_arms=16,
                    pulls_per_round=2, metric="l2")
    res = bmo_nn.knn(corpus, queries, cfg, jax.random.PRNGKey(6))
    n, d = corpus.shape
    slack = cfg.batch_arms * cfg.pulls_per_round * cfg.block  # one round
    assert np.all(np.asarray(res.coord_ops) <= 2 * n * d + slack + n * cfg.init_pulls * cfg.block)


def test_race_returns_k_distinct_sorted():
    corpus, queries = make_knn_benchmark_data("dense", 128, 256, 2, seed=7)
    cfg = BMOConfig(k=5, delta=0.05, block=32, batch_arms=16, metric="l2")
    res = bmo_nn.knn(corpus, queries, cfg, jax.random.PRNGKey(7))
    for i in range(2):
        idx = np.asarray(res.indices[i])
        assert len(set(idx.tolist())) == 5
        vals = np.asarray(res.values[i])
        assert np.all(np.diff(vals) >= -1e-6)  # sorted ascending
