"""Checkpoint manager: roundtrip, atomicity, keep-last-N, async, meta."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore, save
from repro.checkpoint.manager import read_meta


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (4, 8)),
                       "b": jnp.zeros((8,), jnp.bfloat16)},
            "opt": {"m": {"w": jnp.ones((4, 8)), "b": jnp.zeros((8,))}},
            "step": jnp.asarray(17, jnp.int32)}


def test_save_restore_roundtrip(tmp_path):
    st = _state()
    path = str(tmp_path / "ck")
    save(path, st, meta={"step": 17})
    like = jax.eval_shape(lambda: st)
    back = restore(path, like)
    for a, b in zip(jax.tree_util.tree_leaves(st), jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    assert read_meta(path)["step"] == 17


def test_restore_missing_key_raises(tmp_path):
    st = _state()
    path = str(tmp_path / "ck")
    save(path, st)
    like = jax.eval_shape(lambda: {**st, "extra": jnp.zeros(3)})
    with pytest.raises(KeyError):
        restore(path, like)


def test_manager_keep_last_n(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    st = _state()
    for step in (10, 20, 30, 40):
        mgr.save(step, st)
    assert mgr.all_steps() == [30, 40]
    assert mgr.latest_step() == 40


def test_manager_async_save_then_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    st = _state()
    mgr.save(5, st)
    mgr.wait()
    like = jax.eval_shape(lambda: st)
    back, meta = mgr.restore_latest(like)
    assert meta["step"] == 5
    np.testing.assert_array_equal(np.asarray(back["params"]["w"]),
                                  np.asarray(st["params"]["w"]))


def test_manager_empty_dir(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    st, meta = mgr.restore_latest(jax.eval_shape(_state))
    assert st is None and meta is None


def test_atomic_no_tmp_left(tmp_path):
    path = str(tmp_path / "ck")
    save(path, _state())
    assert not os.path.exists(path + ".tmp")
    assert os.path.exists(os.path.join(path, "arrays.npz"))
