"""End-to-end behaviour tests for the paper's system: the full BMO-NN
pipeline (data → bandit search → exact answers → accounting) and the
framework glue (arch registry → train step → checkpoint → serve)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, TrainConfig, get_arch
from repro.configs.base import BMOConfig
from repro.core import bmo_nn, oracle
from repro.data.synthetic import make_knn_benchmark_data
from repro.models import build_model
from repro.train.steps import init_train_state, make_train_step


def test_end_to_end_knn_pipeline(rng):
    """The paper's headline behaviour, end to end: exact k-NN at a fraction
    of the brute-force coordinate budget on clustered high-d data."""
    corpus, queries = make_knn_benchmark_data("dense", 1200, 4096, 6, seed=9)
    ex = oracle.exact_knn(corpus, queries, 5, "l2")
    cfg = BMOConfig(k=5, delta=0.01, block=128, batch_arms=32, metric="l2")
    res = bmo_nn.knn(corpus, queries, cfg, jax.random.PRNGKey(0))
    acc = np.mean([set(np.asarray(res.indices[i]).tolist())
                   == set(np.asarray(ex.indices[i]).tolist()) for i in range(6)])
    gain = float(ex.coord_ops) / float(np.sum(np.asarray(res.coord_ops)))
    assert acc == 1.0
    assert gain > 2.0, gain


def test_end_to_end_train_save_serve(tmp_path, rng):
    """arch config → train a few steps → checkpoint → restore → serve."""
    from repro.checkpoint import CheckpointManager
    from repro.serve.engine import ServeEngine

    entry = get_arch("qwen2.5-14b")
    cfg = entry.smoke
    model = build_model(cfg)
    plan = dataclasses.replace(entry.plan, fsdp=False, tp=False, sp=False,
                               grad_accum=1, param_dtype="float32")
    tcfg = TrainConfig(total_steps=6, lr=1e-3)
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    state = init_train_state(model, plan, tcfg, jax.random.PRNGKey(0))
    step, _ = make_train_step(model, plan, tcfg, mesh)
    jstep = jax.jit(step, donate_argnums=0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)), jnp.int32)}
    for _ in range(3):
        state, metrics = jstep(state, batch)
    ckpt = CheckpointManager(str(tmp_path), keep=1, async_save=False)
    ckpt.save(2, state)
    restored, meta = ckpt.restore_latest(jax.eval_shape(lambda: state))
    assert meta["step"] == 2

    engine = ServeEngine(model, restored["params"], plan, mesh,
                         batch_size=2, max_seq=24)
    prompts = rng.integers(0, cfg.vocab_size, (2, 8)).astype(np.int32)
    out, _ = engine.generate(prompts, 4)
    assert out.shape == (2, 4)


def test_all_archs_registered_and_buildable():
    assert len(ARCHS) == 10
    for a in ARCHS:
        model = build_model(get_arch(a).smoke)
        specs = model.param_specs()
        assert jax.tree_util.tree_leaves(specs)
