"""Unit + property tests for the CI machinery (paper §II-C, Lemma 1)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import confidence as conf


def test_delta_prime_union_bound():
    assert conf.delta_prime(0.1, 100, 50) == pytest.approx(0.1 / 5000)


def test_hoeffding_radius_shrinks_with_count():
    r1 = conf.hoeffding_radius(jnp.asarray(1.0), jnp.asarray(4.0), 5.0)
    r2 = conf.hoeffding_radius(jnp.asarray(1.0), jnp.asarray(16.0), 5.0)
    assert float(r2) == pytest.approx(float(r1) / 2.0)


def test_hoeffding_radius_formula():
    # C = sqrt(2 σ² log(2/δ') / T) — Eq. (3)
    sigma_sq, T, log_term = 2.5, 9.0, 3.0
    want = np.sqrt(2 * sigma_sq * log_term / T)
    got = conf.hoeffding_radius(jnp.asarray(sigma_sq), jnp.asarray(T), log_term)
    assert float(got) == pytest.approx(want)


def test_welford_batch_matches_numpy(rng):
    vals = rng.normal(size=(3, 50)).astype(np.float32)
    mean = jnp.zeros(3)
    count = jnp.zeros(3)
    m2 = jnp.zeros(3)
    # feed in 10 batches of 5
    for i in range(10):
        batch = jnp.asarray(vals[:, i * 5:(i + 1) * 5])
        mean, count, m2 = conf.welford_batch_update(mean, count, m2, batch,
                                                    jnp.ones(3))
    np.testing.assert_allclose(np.asarray(mean), vals.mean(1), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(m2) / 49.0, vals.var(1, ddof=1),
                               rtol=1e-4)


def test_welford_mask_freezes_stats(rng):
    vals = jnp.asarray(rng.normal(size=(2, 4)).astype(np.float32))
    mean = jnp.asarray([1.0, 2.0])
    count = jnp.asarray([3.0, 3.0])
    m2 = jnp.asarray([0.5, 0.5])
    nm, nc, n2 = conf.welford_batch_update(mean, count, m2, vals,
                                           jnp.asarray([1.0, 0.0]))
    assert float(nc[0]) == 7.0 and float(nc[1]) == 3.0
    assert float(nm[1]) == 2.0 and float(n2[1]) == 0.5


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 40), st.integers(2, 8), st.floats(0.1, 10.0))
def test_welford_property_merge_equals_direct(n_batches, bs, scale):
    rng = np.random.default_rng(n_batches * 100 + bs)
    vals = (rng.normal(size=(1, n_batches * bs)) * scale).astype(np.float32)
    mean, count, m2 = jnp.zeros(1), jnp.zeros(1), jnp.zeros(1)
    for i in range(n_batches):
        mean, count, m2 = conf.welford_batch_update(
            mean, count, m2, jnp.asarray(vals[:, i * bs:(i + 1) * bs]),
            jnp.ones(1))
    np.testing.assert_allclose(np.asarray(mean)[0], vals.mean(), rtol=1e-3,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(m2)[0],
                               vals.var() * vals.shape[1], rtol=1e-2,
                               atol=1e-4)


def test_empirical_sigma_shrinkage():
    # few pulls → near global; many pulls → near own variance
    m2 = jnp.asarray([0.0, 1000.0])
    count = jnp.asarray([2.0, 1001.0])
    out = conf.empirical_sigma_sq(m2, count, 1e-12, jnp.asarray(4.0))
    assert float(out[0]) > 2.0          # pulled toward global 4.0
    assert 0.9 < float(out[1]) < 1.1    # own variance ≈ 1.0


def test_pooled_variance():
    m2 = jnp.asarray([2.0, 4.0])
    count = jnp.asarray([3.0, 3.0])
    assert float(conf.pooled_variance(m2, count)) == pytest.approx(6.0 / 4.0)
