import os
import sys

# tests run against the source tree (PYTHONPATH=src also works)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benches must see the real single device; multi-device tests spawn
# subprocesses with their own XLA_FLAGS.

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
