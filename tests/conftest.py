import os
import sys

# tests run against the source tree (PYTHONPATH=src also works)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benches must see the real single device; multi-device tests spawn
# subprocesses with their own XLA_FLAGS.

try:
    import hypothesis  # noqa: F401
except ImportError:  # container without hypothesis: seeded-random fallback
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_stub

    sys.modules["hypothesis"] = _hypothesis_stub

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
