import os
import sys

# tests run against the source tree (PYTHONPATH=src also works)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benches must see the real single device; multi-device tests spawn
# subprocesses with their own XLA_FLAGS.

try:
    import hypothesis  # noqa: F401
except ImportError:  # container without hypothesis: seeded-random fallback
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_stub

    sys.modules["hypothesis"] = _hypothesis_stub

import numpy as np
import pytest

# -- runtime-sanitizer tier (DESIGN.md §12.4) --------------------------------
# REPRO_SANITIZE=1 runs tier-1 with every implicit device->host transfer
# outlawed: only the explicit jax.device_get under the allow-scope inside
# repro.utils.hostsync.host_fetch (and host_boundary blocks) stays legal.
# On CPU the guard cannot trip (host and device memory are one — transfers
# are zero-copy and unguarded), so this tier is a no-op locally and real on
# TPU/GPU backends; wiring it here keeps the discipline testable the day a
# device backend lands. REPRO_SANITIZE=nan additionally arms debug_nans.
_SANITIZE = os.environ.get("REPRO_SANITIZE", "")
if _SANITIZE:
    import jax

    jax.config.update("jax_transfer_guard_device_to_host", "disallow")
    if _SANITIZE == "nan":
        jax.config.update("jax_debug_nans", True)


@pytest.fixture
def rng():
    return np.random.default_rng(0)
